"""Pallas TPU kernel: fused single-dispatch lookup (DESIGN.md §9).

The serving hot path used to be two device dispatches with a host round
trip between them: ``nf_forward_pallas`` (NF transform) then the pure-jnp
``flat_lookup`` while-loop (multi-level FlatAFLI traversal, one full-batch
HBM gather round per tree level).  Learned-index throughput lives and dies
on exactly these per-lookup constant factors (Kraska et al.; Marcus et
al.), so this kernel folds the whole read path into ONE ``pallas_call``:

1. **NF forward** — the unrolled Numerical-NF inference over the [TILE]
   lane batch, via the same ``apply_flow_tile`` helper ``nf_forward_pallas``
   compiles, so build-time and serve-time positioning keys are
   bit-identical;
2. **multi-level traversal** — an in-kernel *unrolled* loop over
   ``max_depth`` (tree heights after the NF transform are 2-3, paper
   Table 1) with per-query active masks.  Each level runs all three node
   resolutions — model-node FMA slot prediction, dense-node
   fixed-iteration binary search, conflict-bucket scan — and selects per
   query, exactly mirroring the ``flat_lookup`` oracle so results are
   bit-identical;
3. **exact identity resolution** — 64-bit (hi, lo) key identity compares,
   emitting payloads in one VMEM round trip;
4. **in-kernel write-path tiers** — the compacted run and active delta
   (log-structured inserts, DESIGN.md §10) ride along as sorted VMEM
   pools probed by bounded binary search + newest-match window scan, so
   mixed read/insert batches stay a single dispatch with no host-side
   delta probe.

The flattened node/entry/bucket pools (``FlatArrays.to_kernel_args``) ride
along as grid-invariant VMEM blocks: after the NF transform the pools are
small enough for VMEM residency on real workloads; the
``kernels/ops.fused_lookup`` shim falls back to the two-dispatch oracle
path when they are not.

Grid: (ceil(B / TILE),) — a real tiled grid over the query batch with
the pools as grid-invariant blocks (DESIGN.md §11).  TILE is
lane-aligned on TPU; the interpret tile is a multi-step-grid throughput
choice (``select_tile``).  Per-level work is batch-gated: the dense
binary search + duplicate scan run only on levels where some live query
sits on a dense node, and each write tier's probe only while the tier
is non-empty.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret
from repro.kernels.nf_forward import DEFAULT_TILE as NF_TILE
from repro.kernels.nf_forward import apply_flow_tile

__all__ = ["fused_lookup_pallas", "KernelPools", "TierPools", "TierPack",
           "DEFAULT_TILE", "INTERPRET_TILE", "NF_TILE", "TOMBSTONE",
           "nf_forward_lanes", "lower_bound", "probe_pool",
           "probe_pool_index"]

DEFAULT_TILE = 512       # lane-aligned query tile for compiled TPU runs
INTERPRET_TILE = 2048    # CPU validation: per-step query tile of the
#                          tiled grid (a 4k+ batch is a multi-step grid,
#                          not one giant block — DESIGN.md §11)

# entry / node codes — schema owned by repro.core.flat_afli
EMPTY, DATA, BUCKET, CHILD = 0, 1, 2, 3
KIND_MODEL, KIND_DENSE = 0, 1

# payload sentinels (DESIGN.md §12): -1 is a miss everywhere; -2 marks a
# tombstoned identity riding the write tiers — a tier probe returning it
# must MASK any older copy below (run / static tree), then surface a miss
TOMBSTONE = -2


# ---------------------------------------------------------------- shared
# traversal helpers, used by this kernel AND kernels/range_scan.py AND
# kernels/streamed_lookup.py (the fused range-scan and HBM-streaming
# paths reuse the same tiled-grid machinery: NF sub-tile discipline,
# bounded lower-bound search, identity-window probes).

def nf_forward_lanes(feat_ref, w_ref, dim: int, shapes) -> jnp.ndarray:
    """NF forward over one [tile] lane batch of expanded features.

    Evaluated in fixed NF_TILE-wide sub-tiles no matter the query tile:
    XLA elementwise codegen (tanh) is 1-ulp shape-dependent, and precise
    placement needs serve-time keys bit-equal to the build transform's
    (which runs the same [NF_TILE] blocks in nf_forward_pallas).  The
    optimization barrier fences each sub-tile from downstream consumers —
    without it XLA horizontally re-fuses the sub-chains into one wide
    (shape-divergent) loop.  Callers must still pin ONE evaluation by
    round-tripping the result through an output ref (see _kernel)."""
    tile_b = feat_ref.shape[0]
    parts = []
    for s in range(0, tile_b, NF_TILE):
        cols = [feat_ref[s:s + NF_TILE, k] for k in range(dim)]
        parts.append(jax.lax.optimization_barrier(
            apply_flow_tile(cols, w_ref, dim, shapes)))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def lower_bound(ppk, n_pool, qkey, iters: int) -> jnp.ndarray:
    """Leftmost index with ``ppk[i] >= qkey`` in a sorted +inf-padded
    pool (== ``np.searchsorted(..., side='left')``), as a fixed
    ``iters``-round binary search (2^iters must cover the pool)."""
    def bs_body(_, lh):
        l, h = lh
        mid = (l + h) // 2
        go_right = ppk[mid] < qkey
        return (jnp.where(go_right, mid + 1, l),
                jnp.where(go_right, h, mid))

    l0 = jnp.zeros(qkey.shape, jnp.int32)
    h0 = jnp.full(qkey.shape, n_pool, jnp.int32)
    l_fin, _ = jax.lax.fori_loop(0, iters, bs_body, (l0, h0))
    return l_fin


def probe_pool_index(phi, plo, n_pool, l_fin, nmax, window: int,
                     qhi, qlo) -> jnp.ndarray:
    """Newest matching *pool index* per lane from one sorted pool
    (-1 = no identity match in the probe window).

    Scans ``[l_fin - window, l_fin + 3*window)`` around the lower-bound
    landing: backward reach for a high landing (a query key 1 ulp above
    the stored key skips its whole equal run), forward reach for a low
    landing plus the equal run itself (each bounded by ``window``, the
    pow2-rounded max equal-key run length of the pool).  Matching is by
    exact (hi, lo) identity ONLY — the positioning key is the locator,
    never the matcher (XLA's per-consumer-shape NF re-materialization is
    1-ulp divergent, so f32 key equality is not codegen-stable).  The
    index form is what the streamed tier accumulates across pool tiles
    (global index order == insertion order, so max-index == newest)."""
    widx = (l_fin - window)[:, None] + jax.lax.broadcasted_iota(
        jnp.int32, (l_fin.shape[0], 4 * window), 1)
    wc = jnp.clip(widx, 0, nmax - 1)
    ok = ((widx >= 0) & (widx < n_pool)
          & (phi[wc] == qhi[:, None])
          & (plo[wc] == qlo[:, None]))
    return jnp.max(jnp.where(ok, widx, -1), axis=1)


def probe_pool(phi, plo, ppv, n_pool, l_fin, nmax, window: int,
               qhi, qlo) -> jnp.ndarray:
    """Newest matching payload per lane from one sorted pool (-1 = miss;
    a matched TOMBSTONE payload passes through for the caller to mask).
    Payload form of ``probe_pool_index`` — see there for the window
    coverage and identity-only matching arguments."""
    last = probe_pool_index(phi, plo, n_pool, l_fin, nmax, window,
                            qhi, qlo)
    pay = ppv[jnp.clip(last, 0, nmax - 1)]
    return jnp.where(last >= 0, pay, -1)


class KernelPools(NamedTuple):
    """Kernel-ready FlatAFLI pools: i32-coded types, lane-padded 1-D
    arrays, conflict buckets flattened row-major to [B * cap].

    Built by ``FlatArrays.to_kernel_args()``; consumed as grid-invariant
    VMEM blocks by ``fused_lookup_pallas``.  (Bucket *keys* are not needed:
    bucket hits resolve purely by 64-bit identity, as in the oracle.)
    """

    node_kind: jnp.ndarray       # i32[N]  model / dense
    node_slope: jnp.ndarray      # f32[N]
    node_intercept: jnp.ndarray  # f32[N]
    node_offset: jnp.ndarray     # i32[N]
    node_size: jnp.ndarray       # i32[N]
    etype: jnp.ndarray           # i32[P]
    ekey: jnp.ndarray            # f32[P]
    ehi: jnp.ndarray             # u32[P]
    elo: jnp.ndarray             # u32[P]
    epayload: jnp.ndarray        # i32[P]
    echild: jnp.ndarray          # i32[P]
    bhi: jnp.ndarray             # u32[B, cap]
    blo: jnp.ndarray             # u32[B, cap]
    bpayload: jnp.ndarray        # i32[B, cap]
    blen: jnp.ndarray            # i32[B]

    def nbytes(self) -> int:
        return int(sum(a.size * a.dtype.itemsize for a in self))


class TierPools(NamedTuple):
    """Device-resident write-path tiers (DESIGN.md §10): the compacted
    sorted run and the active delta, each a lane-padded sorted pool of
    (positioning key, identity bits, payload) plus a length scalar.

    Padding rows carry ``+inf`` keys so the in-kernel binary search never
    lands in them; the length scalar rides in lane 0 of a lane-padded
    vector so every block stays 1-D lane-aligned.  Probed *after* the tree
    traversal with newest-copy-wins precedence: active delta > compacted
    run > static tree.
    """

    run_pk: jnp.ndarray   # f32[R]  sorted positioning keys (+inf padded)
    run_hi: jnp.ndarray   # u32[R]  identity bits
    run_lo: jnp.ndarray   # u32[R]
    run_pv: jnp.ndarray   # i32[R]
    run_len: jnp.ndarray  # i32[lane]  built length at [0]
    dl_pk: jnp.ndarray    # f32[D]  active delta (same layout)
    dl_hi: jnp.ndarray    # u32[D]
    dl_lo: jnp.ndarray    # u32[D]
    dl_pv: jnp.ndarray    # i32[D]
    dl_len: jnp.ndarray   # i32[lane]

    def nbytes(self) -> int:
        return int(sum(a.size * a.dtype.itemsize for a in self))


class TierPack(NamedTuple):
    """TierPools plus their static probe bounds (binary-search iteration
    count per pool and the duplicate-pkey window, both host-computed at
    pack time and rounded so the kernel compile count stays bounded)."""

    pools: TierPools
    run_iters: int
    run_window: int
    delta_iters: int
    delta_window: int

    def nbytes(self) -> int:
        return self.pools.nbytes()


def _kernel(feat_ref, qhi_ref, qlo_ref, w_ref,
            nkind_ref, nslope_ref, nicept_ref, noff_ref, nsize_ref,
            etype_ref, ekey_ref, ehi_ref, elo_ref, epay_ref, echild_ref,
            bhi_ref, blo_ref, bpay_ref, blen_ref,
            rpk_ref, rhi_ref, rlo_ref, rpv_ref, rlen_ref,
            dpk_ref, dhi_ref, dlo_ref, dpv_ref, dlen_ref,
            pay_ref, z_ref, *,
            dim: int, shapes: Tuple[Tuple[int, int], ...], max_depth: int,
            dense_iters: int, bucket_cap: int, dense_window: int,
            use_flow: bool, probe_tiers: bool, run_iters: int,
            run_window: int, delta_iters: int, delta_window: int):
    """One [TILE] query tile: NF forward + full traversal -> payloads.

    Mirrors ``repro.core.flat_afli.flat_lookup`` op-for-op (the oracle);
    any change here must keep the parity tests bit-exact.
    """
    # ---- (1) NF forward: feature columns -> positioning keys.
    # Computed in fixed NF_TILE-wide sub-tiles no matter the query tile:
    # XLA elementwise codegen (tanh) is 1-ulp shape-dependent, and precise
    # placement needs serve-time keys bit-equal to the build transform's
    # (which runs the same [NF_TILE] blocks in nf_forward_pallas).  The
    # optimization barrier fences each sub-tile from the traversal
    # consumers — without it XLA horizontally re-fuses the sub-chains into
    # one wide (shape-divergent) loop.
    if use_flow:
        qkey = nf_forward_lanes(feat_ref, w_ref, dim, shapes)
    else:
        qkey = feat_ref[:, 0]
    # materialize the positioning keys through the output ref: the VMEM
    # round trip pins ONE evaluation of the NF chain.  Without it XLA
    # re-materializes the tanh chain per consumer shape (1-ulp divergent
    # even behind optimization_barrier), and the tier probe's exact
    # f32-equality compares see keys that differ from the emitted z —
    # with it, traversal, tier probe, and the z output are bit-identical
    # by construction.
    z_ref[...] = qkey
    qkey = z_ref[...]
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]

    # pools, VMEM-resident for the whole tile
    nkind = nkind_ref[...]
    nslope = nslope_ref[...]
    nicept = nicept_ref[...]
    noff = noff_ref[...]
    nsize = nsize_ref[...]
    etype = etype_ref[...]
    ekey = ekey_ref[...]
    ehi = ehi_ref[...]
    elo = elo_ref[...]
    epay = epay_ref[...]
    echild = echild_ref[...]
    bhi = bhi_ref[...]
    blo = blo_ref[...]
    bpay = bpay_ref[...]
    blen = blen_ref[...]

    node = jnp.zeros(qkey.shape, jnp.int32)
    result = jnp.full(qkey.shape, -1, jnp.int32)
    done = jnp.zeros(qkey.shape, jnp.bool_)

    # ---- (2) bounded traversal: early-exit while_loop over levels with
    # per-query active masks, exactly as the flat_lookup oracle runs it (a
    # loop, not a python unroll — compile time stays flat in tree height).
    # NOTE gather idiom: plain ``pool[idx]`` indexing, never
    # ``jnp.take(pool, idx)``.  Both clamp out-of-bounds reads, but the
    # explicit clip-mode gather take() emits defeats XLA:CPU
    # vectorization and ran the whole traversal ~2x slower than the
    # flat_lookup oracle (the BENCH_fused_lookup traversal_only.speedup
    # = 0.79 anomaly); indexing compiles to the same gather the oracle
    # uses, restoring parity op-for-op.
    def level_body(carry):
        node, result, done, depth = carry
        kind = nkind[node]
        slope = nslope[node]
        intercept = nicept[node]
        offset = noff[node]
        size = nsize[node]

        # model-node path: precise predicted slot (f32 FMA, as built)
        slot = jnp.clip(
            jnp.rint(slope * qkey + intercept).astype(jnp.int32), 0, size - 1
        )
        e_model = offset + slot
        is_dense = kind == KIND_DENSE

        # dense-node path, level-gated: the fixed-iteration binary
        # search + duplicate-run scan are the dominant per-level gather
        # cost (dense_iters rounds), but NF-transformed trees are
        # model-node-heavy — most levels have NO live query on a dense
        # node.  ``lax.cond`` on the batch-collective predicate skips
        # the whole stage for such levels; ``dense_payload`` feeds only
        # ``is_dense`` lanes, so the skip is bit-invisible (this is
        # where the fused path overtakes the unconditionally-searching
        # flat_lookup oracle on traversal-only workloads).
        def dense_stage(_):
            def bs_body(_, lh):
                l, h = lh
                mid = (l + h) // 2
                v = ekey[mid]
                go_right = v < qkey
                return (jnp.where(go_right, mid + 1, l),
                        jnp.where(go_right, h, mid))

            l_fin, _ = jax.lax.fori_loop(0, dense_iters, bs_body,
                                         (offset, offset + size))
            e_dense = jnp.clip(l_fin, offset, offset + size - 1)

            # dense duplicates of an f32 pkey: bounded forward scan, done
            # as one [tile, window] vectorized gather round; the first
            # matching position wins (argmax picks the first True),
            # exactly the oracle's acc<0 first-match fold
            widx = jnp.clip(
                e_dense[:, None]
                + jax.lax.broadcasted_iota(jnp.int32, (e_dense.shape[0],
                                                       dense_window), 1),
                offset[:, None], (offset + size - 1)[:, None])
            wok = ((ekey[widx] == qkey[:, None])
                   & (ehi[widx] == qhi[:, None])
                   & (elo[widx] == qlo[:, None]))
            # argmax < window by construction, so the column pick can
            # promise in-bounds — the default fill-mode gather would
            # devectorize exactly like the PR 3 clip-mode take
            first = jnp.argmax(wok, axis=1)
            found = jnp.take_along_axis(
                wok, first[:, None], 1, mode="promise_in_bounds")[:, 0]
            wpay = jnp.take_along_axis(
                epay[widx], first[:, None], 1,
                mode="promise_in_bounds")[:, 0]
            return e_dense, jnp.where(found, wpay, -1)

        def dense_skip(_):
            # no live dense-node query this level: e_dense only feeds
            # is_dense lanes (none live) so any in-range entry index is
            # equivalent; offset is always valid
            return offset, jnp.full(offset.shape, -1, jnp.int32)

        e_dense, dense_payload = jax.lax.cond(
            jnp.any(is_dense & ~done), dense_stage, dense_skip, None)

        e = jnp.where(kind == KIND_MODEL, e_model, e_dense)
        et = etype[e]

        # (3) exact 64-bit identity resolution
        hit_data = (et == DATA) & (ehi[e] == qhi) & (elo[e] == qlo)

        # conflict-bucket scan: one row gather over the fixed capacity
        # (max over where(match, payload, -1), as in the oracle)
        bid = jnp.maximum(echild[e], 0)
        brow_hi = bhi[bid]                           # [tile, cap]
        brow_lo = blo[bid]
        brow_pv = bpay[bid]
        col = jax.lax.broadcasted_iota(jnp.int32, brow_hi.shape, 1)
        bmatch = ((brow_hi == qhi[:, None]) & (brow_lo == qlo[:, None])
                  & (col < blen[bid][:, None]))
        bucket_payload = jnp.max(jnp.where(bmatch, brow_pv, -1), axis=-1)

        model_payload = jnp.where(
            hit_data, epay[e],
            jnp.where(et == BUCKET, bucket_payload, -1),
        )
        result = jnp.where(
            done, result, jnp.where(is_dense, dense_payload, model_payload)
        )
        goes_deeper = (~is_dense) & (et == CHILD) & (~done)
        node = jnp.where(goes_deeper, echild[e], node)
        done = done | ~goes_deeper
        return node, result, done, depth + 1

    def level_cond(carry):
        _, _, done, depth = carry
        return (~jnp.all(done)) & (depth < max_depth)

    _, result, _, _ = jax.lax.while_loop(level_cond, level_body,
                                         (node, result, done, 0))

    # ---- (4) write-path tiers (DESIGN.md §10): probe the compacted run
    # and the active delta in-kernel so a mixed read/insert batch never
    # needs a host-side delta round trip.  Each tier is a sorted pool:
    # bounded binary search locates the equal-key neighborhood, then a
    # static window scan resolves by exact (hi, lo) identity ONLY — the
    # positioning key is the locator, never the matcher.  That split is
    # load-bearing: XLA re-materializes the NF tanh chain per consumer
    # shape (1-ulp divergent even behind optimization_barrier), so an
    # f32-equality compare against qkey is not codegen-stable, but a
    # +/-1-ulp perturbed qkey still lands the search within the adjacent
    # equal-key runs (no f32 value exists strictly between 1-ulp
    # neighbors), and the symmetric window covers them.  The NEWEST
    # matching copy wins — tiers keep insertion order within an
    # equal-pkey window (stable sort), so the largest matching index is
    # the last write — and the freshest tier takes precedence:
    # active delta > compacted run > static tree.  Mirrors the host
    # ``FlatAFLI._probe_delta`` oracle; parity must stay exact.
    if probe_tiers:
        def tier_stage(phi, plo, ppv, ppk, n_pool, iters, window, nmax):
            # length-gated: a tier that is empty right now (e.g. the run
            # between a fold swap and the first shadow) skips its whole
            # search+scan; misses are the only possible outcome anyway
            def live(_):
                return probe_pool(phi, plo, ppv, n_pool,
                                  lower_bound(ppk, n_pool, qkey, iters),
                                  nmax, window, qhi, qlo)

            def empty(_):
                return jnp.full(qkey.shape, -1, jnp.int32)

            return jax.lax.cond(n_pool > 0, live, empty, None)

        run_pay = tier_stage(rhi_ref[...], rlo_ref[...], rpv_ref[...],
                             rpk_ref[...], rlen_ref[...][0], run_iters,
                             run_window, rpk_ref.shape[0])
        dl_pay = tier_stage(dhi_ref[...], dlo_ref[...], dpv_ref[...],
                            dpk_ref[...], dlen_ref[...][0], delta_iters,
                            delta_window, dpk_ref.shape[0])
        # an identity MATCH in a newer tier always wins — including a
        # TOMBSTONE (-2) match, which must mask any older copy below
        # rather than fall through to it; the final mapping surfaces
        # tombstones as misses
        result = jnp.where(dl_pay != -1, dl_pay,
                           jnp.where(run_pay != -1, run_pay, result))
        result = jnp.where(result == TOMBSTONE, -1, result)

    pay_ref[...] = result


def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def select_tile(b: int, use_flow: bool, tile: Optional[int] = None,
                interpret: Optional[bool] = None) -> int:
    """Query-tile selection for the tiled grid (DESIGN.md §11).

    The batch is served as a grid over query tiles with the pools as
    grid-invariant blocks.  Flow tiles are pinned to whole ``NF_TILE``
    multiples (build/serve key bit-equality, see module docstring); the
    no-flow tile is a pure throughput choice: power-of-two bucketed so
    per-batch-size recompiles stay bounded, capped at ``DEFAULT_TILE``
    compiled / ``INTERPRET_TILE`` interpreted so a large batch becomes a
    multi-step grid instead of one giant block.  Exposed so the dispatch
    shim can bill the per-step query blocks against the VMEM budget with
    the same tile the kernel will actually use."""
    interpret = resolve_interpret(interpret)
    if use_flow:
        if tile is None:
            tile = NF_TILE
        # whole sub-tiles only: a ragged final sub-tile would evaluate
        # the NF on a different shape and break key bit-equality
        return ((max(tile, NF_TILE) + NF_TILE - 1) // NF_TILE) * NF_TILE
    if tile is None:
        tile = INTERPRET_TILE if interpret else DEFAULT_TILE
    # never pad a small batch up to a huge tile; stay lane-aligned on TPU
    tile = min(tile, _pow2ceil(b))
    return tile if interpret else max(tile, 128)


@functools.partial(
    jax.jit,
    static_argnames=("dim", "shapes", "max_depth", "dense_iters",
                     "bucket_cap", "dense_window", "use_flow", "tile",
                     "interpret", "probe_tiers", "run_iters", "run_window",
                     "delta_iters", "delta_window"),
)
def fused_lookup_pallas(
    feats: jnp.ndarray,
    qhi: jnp.ndarray,
    qlo: jnp.ndarray,
    packed_w: jnp.ndarray,
    pools: KernelPools,
    tiers: Optional[TierPools] = None,
    *,
    dim: int,
    shapes: Tuple[Tuple[int, int], ...] = (),
    max_depth: int,
    dense_iters: int,
    bucket_cap: int,
    dense_window: int = 8,
    use_flow: bool = True,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
    probe_tiers: bool = False,
    run_iters: int = 1,
    run_window: int = 4,
    delta_iters: int = 1,
    delta_window: int = 4,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused NF-transform + FlatAFLI traversal in one ``pallas_call``.

    feats: [B, d] f32 expanded query features (``use_flow=True``) or
    [B, 1] positioning keys (``use_flow=False``); qhi/qlo: [B] u32 exact
    identity bits; packed_w: [1, n] ``pack_flow_weights`` block (any
    [1, >=1] f32 array when ``use_flow=False``).

    Returns (payload i32[B] or -1, positioning key f32[B]).  When
    ``tiers``/``probe_tiers`` is set, the write-path tiers (compacted run
    + active delta, DESIGN.md §10) are probed in-kernel after the
    traversal with newest-copy-wins precedence, so a mixed read/insert
    batch needs no host-side delta probe; otherwise the key output feeds
    the host ``_probe_delta`` fallback.  Bit-identical to
    ``nf_forward_pallas`` + ``flat_lookup`` (+ the host tier probe) by
    construction.  ``interpret=None`` auto-detects the backend.

    Tile discipline (DESIGN.md §9): the in-kernel NF always evaluates in
    fixed [NF_TILE] sub-tiles.  XLA's tanh codegen is 1-ulp
    shape-dependent, so serve-time NF output is bit-equal to the build-time
    transform (``nf_transform_keys``, same block shape) only when the
    evaluated shape matches — and precise placement rides on that equality.
    The traversal itself uses only IEEE-exact ops
    (mul/add/rint/compare/gather) and is shape-robust, so the query tile is
    a pure throughput choice (rounded to an NF_TILE multiple under flow).
    """
    interpret = resolve_interpret(interpret)
    if tiers is None:
        # no write tiers: ride tiny dummy blocks through the call (the
        # probe stage is compiled out by the static flag)
        probe_tiers = False
        lane = jnp.zeros((128,), jnp.int32)
        tiers = TierPools(
            run_pk=jnp.full((128,), jnp.inf, jnp.float32),
            run_hi=jnp.zeros((128,), jnp.uint32),
            run_lo=jnp.zeros((128,), jnp.uint32),
            run_pv=jnp.full((128,), -1, jnp.int32), run_len=lane,
            dl_pk=jnp.full((128,), jnp.inf, jnp.float32),
            dl_hi=jnp.zeros((128,), jnp.uint32),
            dl_lo=jnp.zeros((128,), jnp.uint32),
            dl_pv=jnp.full((128,), -1, jnp.int32), dl_len=lane,
        )
    b = feats.shape[0]
    # tiled grid over the query batch (pools ride as grid-invariant
    # blocks).  Flow tiles are pinned: the NF must evaluate on the build
    # transform's block shape for bit-equal serve-time keys (see
    # docstring) — sub-tiling plus an optimization barrier narrows but
    # does not close the gap, so only NF_TILE multiples are safe.
    tile = select_tile(b, use_flow, tile, interpret)
    b_pad = ((b + tile - 1) // tile) * tile
    if b_pad != b:
        feats = jnp.pad(feats, ((0, b_pad - b), (0, 0)))
        qhi = jnp.pad(qhi, (0, b_pad - b))
        qlo = jnp.pad(qlo, (0, b_pad - b))

    qspec = pl.BlockSpec((tile,), lambda i: (i,))
    fspec = pl.BlockSpec((tile, feats.shape[1]), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, packed_w.shape[1]), lambda i: (0, 0))

    def pool_spec(a):
        return pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)

    pay, z = pl.pallas_call(
        functools.partial(
            _kernel, dim=dim, shapes=shapes, max_depth=max_depth,
            dense_iters=dense_iters, bucket_cap=bucket_cap,
            dense_window=dense_window, use_flow=use_flow,
            probe_tiers=probe_tiers, run_iters=run_iters,
            run_window=run_window, delta_iters=delta_iters,
            delta_window=delta_window,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b_pad,), jnp.int32),
            jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        ),
        grid=(b_pad // tile,),
        in_specs=[fspec, qspec, qspec, wspec]
        + [pool_spec(a) for a in pools] + [pool_spec(a) for a in tiers],
        out_specs=(qspec, qspec),
        interpret=interpret,
    )(feats.astype(jnp.float32), qhi, qlo, packed_w.astype(jnp.float32),
      *pools, *tiers)
    return pay[:b], z[:b]
