"""Shard router for key-space-partitioned serving (DESIGN.md §13).

The key domain is split into P contiguous shards in *positioning-key
space* (z-space when the flow is on): shard ``s`` owns ``[B[s-1], B[s])``
for a sorted f32 boundary vector ``B`` of length P-1 (implicit -inf /
+inf sentinels at the ends).  Boundaries are chosen from the CDF of the
trained flow — equal-mass quantiles of the transformed build keys — so
shards are balanced in z-space no matter how skewed the raw keys are
(Kraska et al.'s top-level dispatcher, realized as a binary search over
P-1 floats instead of a learned sub-model: for contiguous balanced
partitions the CDF quantiles ARE the optimal top-level model).

Routing is **jit-fused**: one compiled dispatch takes a query batch and
emits ``(z, shard_id)`` — with the flow on, the NF forward
(``nf_forward_pallas``, the same fixed-tile kernel that positioned the
build) and the boundary lower-bound run inside a single jit computation,
so the router costs one dispatch regardless of P.  The per-query work is
a [B]-lane ``searchsorted`` over P-1 boundaries — O(log P) vector ops —
which is why the router is jnp inside jit rather than a dedicated Pallas
kernel: the NF forward dominates, and it already IS one.

The host-side helpers (`bin_by_shard`, `split_ranges`) turn routed ids
into the per-shard fan-out plan: stable binning that preserves intra-
shard request order (writes stay age-ordered per shard) plus the inverse
permutation that restores input order at gather time, and per-shard
sub-range splitting for range queries that straddle a boundary.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "choose_boundaries",
    "refresh_boundaries",
    "route",
    "route_flow",
    "bin_by_shard",
    "fanout_plan",
    "split_ranges",
]


def choose_boundaries(pk32_sorted: np.ndarray, n_shards: int) -> np.ndarray:
    """Equal-mass shard boundaries from the build snapshot's CDF.

    ``pk32_sorted``: the f32 positioning keys (the flow's z values when
    the flow is on) in ascending order — their empirical CDF is the
    trained flow's CDF over the keyset.  Returns f32[``n_shards - 1``]
    ascending boundaries at the ``s / n_shards`` quantiles; shard ``s``
    owns ``[B[s-1], B[s])``.  Duplicate-heavy keysets can yield equal
    boundaries (an empty shard), which the serving layer tolerates —
    balance degrades, correctness does not.
    """
    n = int(pk32_sorted.shape[0])
    P = int(n_shards)
    if P < 2:
        return np.empty(0, np.float32)
    idx = (np.arange(1, P, dtype=np.int64) * n) // P
    b = np.asarray(pk32_sorted, np.float32)[np.clip(idx, 0, max(n - 1, 0))]
    return np.ascontiguousarray(b, np.float32)


@jax.jit
def _splice_boundaries(boundaries: jnp.ndarray, interior: jnp.ndarray,
                       lo: jnp.ndarray) -> jnp.ndarray:
    """Value-only boundary refresh for a §18 migration swap: write the
    window's ``k - 1`` new interior boundaries over positions
    ``lo .. lo + k - 2`` of the f32[P-1] boundary vector.  The window
    offset rides as a TRACED scalar (``dynamic_update_slice`` start),
    and the output length equals the input length — so this dispatch,
    and every downstream consumer of the refreshed vector
    (``_route_flow`` takes boundaries as a traced argument), reuses its
    compiled trace no matter which window migrates.  The §17 streamed
    router is untouched by construction: its shape derives from pool
    capacity, never from boundary values."""
    return jax.lax.dynamic_update_slice(boundaries, interior, (lo,))


def refresh_boundaries(boundaries, interior, lo: int) -> np.ndarray:
    """Host wrapper for the migration-swap boundary splice: validate the
    window, run the jitted ``_splice_boundaries``, and check that the
    refreshed vector is still non-decreasing (a splice that breaks the
    routing order would silently mis-route every query past the window —
    fail loudly instead; the §18 coordinator derives interior boundaries
    from the window's own key mass, which cannot cross the outer
    boundaries, so this never trips in normal operation).  Returns the
    new f32[P-1] host vector; the caller republishes the device copy."""
    b = np.asarray(boundaries, np.float32)
    it = np.asarray(interior, np.float32)
    lo = int(lo)
    if it.shape[0] == 0:
        return b.copy()
    if lo < 0 or lo + it.shape[0] > b.shape[0]:
        raise ValueError(
            f"boundary splice [{lo}, {lo + it.shape[0]}) outside the "
            f"boundary vector of length {b.shape[0]}")
    out = np.asarray(_splice_boundaries(
        jnp.asarray(b), jnp.asarray(it), jnp.asarray(lo, jnp.int32)))
    if out.shape[0] > 1 and np.any(np.diff(out) < 0):
        raise ValueError("boundary splice breaks routing monotonicity")
    return np.ascontiguousarray(out, np.float32)


def route(z32: np.ndarray, boundaries) -> np.ndarray:
    """Route positioning keys (flow off, or pre-transformed z) to shard
    ids: the boundary lower-bound count (#B <= z).  Pure host numpy —
    P-1 floats do not warrant a device dispatch, and the f32
    ``searchsorted`` semantics are identical to the fused router's
    in-jit binning (``route_flow``), so the two routes can never
    disagree.  Empty boundaries = one shard."""
    z32 = np.asarray(z32, np.float32)
    if boundaries is None or boundaries.shape[0] == 0:
        return np.zeros(z32.shape[0], np.int32)
    return np.searchsorted(np.asarray(boundaries, np.float32), z32,
                           side="right").astype(np.int32)


@functools.partial(jax.jit, static_argnames=("dim", "shapes"))
def _route_flow(feats: jnp.ndarray, packed_w: jnp.ndarray,
                boundaries: jnp.ndarray, *, dim: int, shapes):
    """Fused NF forward + boundary lower-bound: ONE compiled dispatch
    from raw query features to (z, shard id).  The NF runs through
    ``nf_forward_pallas`` — the same fixed-``DEFAULT_TILE`` kernel that
    produced the build-time positioning keys (``ops.nf_transform_keys``)
    — so the routed z is bit-identical to the z each shard was built
    and is probed with (§8/§13: one NF path end to end, no in-kernel
    re-materialization hazard on the sharded route)."""
    from repro.kernels.nf_forward import nf_forward_pallas

    z = nf_forward_pallas(feats, packed_w, shapes, dim)
    return z, jnp.searchsorted(boundaries, z, side="right").astype(jnp.int32)


def route_flow(feats: np.ndarray, packed_w, shapes,
               boundaries) -> Tuple[np.ndarray, np.ndarray]:
    """Flow-on routing: expanded query features -> ``(z f32[n],
    shard_id i32[n])`` in one fused dispatch.  Pads the batch to the
    shared power-of-two bucket (``backend.pow2_batch``) so ragged
    request sizes reuse a bounded set of traces, exactly like the
    per-shard serve dispatches."""
    from repro.kernels.backend import pow2_batch

    feats = np.asarray(feats, np.float32)
    n = feats.shape[0]
    n_pad = pow2_batch(n)
    if n_pad != n:
        feats = np.pad(feats, ((0, n_pad - n), (0, 0)))
    if boundaries is None or boundaries.shape[0] == 0:
        from repro.kernels.nf_forward import nf_forward_pallas

        z = nf_forward_pallas(jnp.asarray(feats), packed_w, shapes,
                              feats.shape[1])
        return np.asarray(z)[:n], np.zeros(n, np.int32)
    z, sid = _route_flow(jnp.asarray(feats), packed_w,
                         jnp.asarray(boundaries), dim=feats.shape[1],
                         shapes=tuple(shapes))
    return np.asarray(z)[:n], np.asarray(sid)[:n]


def bin_by_shard(sids: np.ndarray, n_shards: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fan-out plan from routed shard ids.

    Returns ``(order, counts, inv)``: ``order`` is a stable permutation
    grouping queries by shard (shard-major, input order *within* each
    shard preserved — per-shard write batches stay age-ordered, which
    the tiers' last-write-wins dedup relies on); ``counts[s]`` is shard
    s's group length (group s occupies
    ``order[counts[:s].sum() : counts[:s+1].sum()]``); ``inv`` is the
    inverse permutation — ``gathered[inv]`` restores input order from
    shard-major results."""
    sids = np.asarray(sids)
    order = np.argsort(sids, kind="stable")
    counts = np.bincount(sids, minlength=n_shards).astype(np.int64)
    inv = np.empty_like(order)
    inv[order] = np.arange(order.shape[0])
    return order, counts, inv


def fanout_plan(sids: np.ndarray, n_shards: int
                ) -> Tuple[list, np.ndarray]:
    """``bin_by_shard`` unrolled into per-shard segments.

    Returns ``(segments, inv)``: ``segments[s]`` is the stable index
    array of the queries routed to shard ``s`` (input order preserved
    within the shard — write batches stay age-ordered), and ``inv``
    restores input order from the shard-major concatenation of
    non-empty segment results.  Every fan-out call site walks this
    exact plan, so the offset arithmetic lives in one place."""
    order, counts, inv = bin_by_shard(sids, int(n_shards))
    offs = np.zeros(int(n_shards) + 1, np.int64)
    np.cumsum(counts, out=offs[1:])
    segs = [order[offs[s]:offs[s + 1]] for s in range(int(n_shards))]
    return segs, inv


def split_ranges(zlo: np.ndarray, zhi: np.ndarray, boundaries
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Split ``[zlo, zhi)`` range queries at shard boundaries.

    A range that straddles boundaries becomes one sub-range per touched
    shard: shard ``s`` in ``[first, last]`` gets
    ``[max(zlo, B[s-1]), min(zhi, B[s]))`` — the sub-ranges tile the
    original half-open interval exactly, and because every shard's pools
    hold only in-domain keys the per-shard scans are disjoint and their
    shard-ordered concatenation is the global positioning-key order
    (DESIGN.md §13 merge semantics).

    Returns flat sub-query arrays ``(qid i64[m], sid i32[m],
    sub_lo f32[m], sub_hi f32[m])``, shard-id ascending within each
    query; empty ranges (``zhi <= zlo``) contribute no sub-queries.
    """
    zlo = np.asarray(zlo, np.float32)
    zhi = np.asarray(zhi, np.float32)
    B = (np.empty(0, np.float32) if boundaries is None
         else np.asarray(boundaries, np.float32))
    nonempty = zhi > zlo
    # first shard touched: lower-bound of zlo (#B <= zlo); last shard
    # touched: #B < zhi (a range ending exactly AT a boundary does not
    # touch the shard that starts there)
    first = np.searchsorted(B, zlo, side="right").astype(np.int64)
    last = np.searchsorted(B, zhi, side="left").astype(np.int64)
    spans = np.where(nonempty, last - first + 1, 0)
    qid = np.repeat(np.arange(zlo.shape[0], dtype=np.int64), spans)
    excl = np.cumsum(spans) - spans  # exclusive cumsum, shape-safe at n=0
    step = np.arange(int(spans.sum()), dtype=np.int64) - np.repeat(excl, spans)
    sid = (np.repeat(first, spans) + step).astype(np.int32)
    # clip each sub-range to its shard's domain [B[s-1], B[s])
    ext = np.concatenate([[-np.inf], B, [np.inf]]).astype(np.float32)
    sub_lo = np.maximum(zlo[qid], ext[sid])
    sub_hi = np.minimum(zhi[qid], ext[sid + 1])
    return qid, sid, sub_lo, sub_hi
