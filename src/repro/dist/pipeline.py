"""GPipe pipeline parallelism over a named mesh axis (shard_map-native).

``pipeline_apply`` runs a stage function over a microbatched input with
the classic GPipe schedule: with S stages and M microbatches, tick t has
stage s processing microbatch t - s, results hopping one stage per tick
via ``ppermute``.  M + S - 1 ticks drain the pipe; the LAST stage's rank
holds the final outputs (callers broadcast over the pipe axis if they
need them replicated — see tests/test_pipeline.py).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.collectives import static_axis_size

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   params: Any, xs: jnp.ndarray,
                   axis_name: str) -> jnp.ndarray:
    """Run ``stage_fn`` as a GPipe pipeline over mesh axis ``axis_name``.

    params: THIS rank's stage parameters (stage s holds stage-s weights);
    xs: [M, ...] microbatches, replicated over the pipe axis;
    returns [M, ...] stage-(S-1) outputs, valid on the last pipe rank.
    """
    n_stages = static_axis_size(axis_name)
    stage = jax.lax.axis_index(axis_name)
    m = xs.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    recv = jnp.zeros_like(xs[0])
    out = jnp.zeros_like(xs)
    for t in range(m + n_stages - 1):
        mb = t - stage                      # microbatch index at this rank
        active = (mb >= 0) & (mb < m)
        # stage 0 pulls from the microbatch stream; later stages from the
        # previous stage's wire
        x_in = jnp.where(stage == 0, xs[min(t, m - 1)], recv)
        y = stage_fn(params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # the last stage banks its finished microbatch
        bank = jax.lax.dynamic_update_index_in_dim(
            out, y, jnp.clip(mb, 0, m - 1), axis=0)
        out = jnp.where((stage == n_stages - 1) & active, bank, out)
        # one hop down the pipe
        recv = jax.lax.ppermute(y, axis_name, perm)
    return out
