"""Compressed collectives + the distributed flash-decode combiner.

``compressed_psum`` is the gradient-compression building block: int8
quantize locally, move the *quantized* payload over the interconnect
(all-gather), dequantize and reduce locally — 4x less wire traffic than
an f32 psum at <1% relative error (scales travel alongside, one f32 per
row).

``flash_decode_combine`` merges per-shard partial attention results when
the KV sequence axis is sharded: the standard streaming-softmax
combination (running max + rescaled partial sums), executed once across
the mesh axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8", "dequantize_int8", "compressed_psum",
    "flash_decode_combine", "static_axis_size",
]


def static_axis_size(axis_name: str) -> int:
    """Size of a named mesh axis from inside shard_map, as a python int."""
    try:  # jax >= 0.4.3x keeps the axis env here
        from jax._src.core import get_axis_env

        return int(get_axis_env().axis_size(axis_name))
    except Exception:
        frame = jax.core.axis_frame(axis_name)  # older fallback
        return int(getattr(frame, "size", frame))


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 quantization: x ~= q * scale.

    Returns (q int8[..., n], scale f32[..., 1]).  Row granularity keeps
    the error bounded by the row's own dynamic range.
    """
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-compressed all-reduce over ``axis_name``.

    The int8 payload (plus one f32 scale per row) crosses the wire; the
    f32 reduction happens after local dequantization, so the only error
    is the local quantization error.
    """
    q, scale = quantize_int8(x)
    q_all = jax.lax.all_gather(q, axis_name)          # [A, ...] int8 wire
    s_all = jax.lax.all_gather(scale, axis_name)      # [A, ..., 1] f32
    return jnp.sum(dequantize_int8(q_all, s_all), axis=0)


def flash_decode_combine(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray,
                         axis_name: str) -> jnp.ndarray:
    """Combine per-shard flash-decode partials across a sharded KV axis.

    Each shard contributes ``o = sum_s exp(s - m) v`` (unnormalized),
    ``m = max_s s`` and ``l = sum_s exp(s - m)`` over its local KV slice.
    The global result rescales every partial to the global max and
    normalizes:  softmax(s) @ v  ==  psum(o * alpha) / psum(l * alpha)
    with alpha = exp(m - pmax(m)).

    o: [..., D]; m, l: [...] (o without the feature dim).
    """
    m_glob = jax.lax.pmax(m, axis_name)
    alpha = jnp.exp(m - m_glob)
    o_sum = jax.lax.psum(o * alpha[..., None], axis_name)
    l_sum = jax.lax.psum(l * alpha, axis_name)
    return o_sum / l_sum[..., None]
