"""Logical-axis sharding: one rule table, mesh-shape-aware lowering.

Model code names *logical* axes ("batch", "mlp", "fsdp", ...); the mesh
names *physical* axes ("pod", "data", "model", ...).  ``LOGICAL_RULES``
maps the former to candidate lists of the latter, and the lowering here
filters those candidates against the mesh that is actually present —
the same model code runs on a laptop CPU (no mesh: everything is a
no-op), a single pod, or a multi-pod mesh.

Invariants enforced when lowering one spec:

* a mesh axis is used at most once per spec (XLA requirement);
* ``guarded_spec`` additionally drops axes a dimension cannot divide, so
  odd shapes (ragged batches, smoke configs) never fail to compile.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "LOGICAL_RULES", "logical_to_spec", "guarded_spec", "constrain",
    "mesh_scope", "current_mesh", "named_sharding", "param_sharding",
    "shard_mesh",
]

# logical axis -> ordered candidate mesh axes (filtered by mesh presence)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    # activation batch: sharded over every data-parallel axis present
    "batch": ("pod", "data"),
    # decode-time batch that may additionally fold over the model axis
    "batch_model": ("pod", "data", "model"),
    # fully-sharded parameter dim (zero-style) over the data axis
    "fsdp": ("data",),
    # tensor-parallel dims
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "d_inner": ("model",),
    # pipeline stages
    "stage": ("pipe",),
    # key-space shard of the learned-index serving layer (DESIGN.md §13)
    "shard": ("shard",),
    # replicated-by-default dims (named for documentation value)
    "embed": (),
    "seq": (),
    "kv_seq": (),
    "conv": (),
    "h": (),
    "wo": (),
}


def _axis_sizes(mesh) -> Dict[str, int]:
    """Mesh axis sizes as a plain dict (works for jax Mesh and test fakes)."""
    return dict(mesh.shape)


def _lower(axes: Sequence[Optional[str]], mesh,
           shape: Optional[Sequence[int]] = None) -> P:
    sizes = _axis_sizes(mesh)
    used: set = set()
    parts = []
    for i, name in enumerate(axes):
        if name is None:
            parts.append(None)
            continue
        cands = [a for a in LOGICAL_RULES.get(name, ())
                 if a in sizes and a not in used]
        if shape is not None:
            # drop trailing candidates until the dim divides their product
            dim = shape[i]
            while cands:
                prod = 1
                for a in cands:
                    prod *= int(sizes[a])
                if dim % prod == 0:
                    break
                cands = cands[:-1]
        if not cands:
            parts.append(None)
            continue
        used.update(cands)
        parts.append(cands[0] if len(cands) == 1 else tuple(cands))
    return P(*parts)


def logical_to_spec(axes: Sequence[Optional[str]], mesh) -> P:
    """Lower logical axis names to a PartitionSpec for ``mesh``."""
    return _lower(axes, mesh)


def guarded_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 mesh) -> P:
    """``logical_to_spec`` that also drops axes ``shape`` cannot divide."""
    return _lower(axes, mesh, shape=shape)


def shard_mesh(n_shards: int, axis: str = "shard"):
    """1-D device mesh for key-space-sharded index serving (DESIGN.md
    §13): shard ``s`` of the partitioned key domain lives on
    ``devices[s]``.

    Returns ``(mesh, devices)`` where ``devices`` has exactly
    ``n_shards`` entries — when the host exposes fewer physical devices
    than shards (the CPU validation platform without
    ``--xla_force_host_platform_device_count``), shards wrap round-robin
    onto the available devices and the mesh covers the distinct devices
    actually used.  ``mesh`` is ``None`` for the degenerate single-
    device case so callers can treat it as the usual no-mesh scope."""
    avail = jax.devices()
    devices = [avail[s % len(avail)] for s in range(max(int(n_shards), 1))]
    distinct = list(dict.fromkeys(devices))
    if len(distinct) < 2:
        return None, devices
    import numpy as _np

    mesh = jax.sharding.Mesh(_np.asarray(distinct), (axis,))
    return mesh, devices


# --------------------------------------------------------------- mesh scope
_MESH_STACK: list = []


@contextlib.contextmanager
def mesh_scope(mesh):
    """Ambient mesh for ``constrain``; ``None`` is a no-op scope (CPU)."""
    if mesh is None:
        yield None
        return
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    return _MESH_STACK[-1] if _MESH_STACK else None


def constrain(x, *axes: Optional[str]):
    """Sharding constraint by logical axis names; identity without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = guarded_spec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------- shardings
def named_sharding(mesh, axes: Optional[Sequence[Optional[str]]] = None
                   ) -> NamedSharding:
    """NamedSharding from logical axes (replicated when ``axes`` is None)."""
    spec = P() if axes is None else logical_to_spec(axes, mesh)
    return NamedSharding(mesh, spec)


def _is_axes_leaf(v: Any) -> bool:
    return isinstance(v, tuple) and all(
        a is None or isinstance(a, str) for a in v)


def param_sharding(specs, mesh):
    """Logical-axes spec tree (``model.param_specs()``) -> sharding tree."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_to_spec(axes, mesh)),
        specs, is_leaf=_is_axes_leaf)
