"""Distribution layer: logical sharding rules, compressed collectives,
and pipeline parallelism for the production serving/training stack."""
