"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the internlm2 family at a width that lands near 100M params, the
synthetic Markov corpus (learnable n-gram structure), AdamW + cosine,
checkpointing every 50 steps.  Loss must drop well below the unigram
entropy to demonstrate real learning.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import AttnConfig
from repro.data.tokens import SyntheticTokens
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def lm_100m():
    base = get_config("internlm2-1.8b")
    return dataclasses.replace(
        base,
        n_layers=8,
        d_model=512,
        d_ff=2048,
        vocab=4096,
        attn=AttnConfig(n_heads=8, kv_heads=4, head_dim=64),
        param_dtype="float32",
        compute_dtype="float32",
        loss_chunk=64,
        remat="none",
        tie_embeddings=False,
    )  # ~34M backbone + embeddings ~8.4M -> runs in minutes on CPU


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true",
                    help="keep an existing checkpoint dir (default: fresh)")
    args = ap.parse_args()

    if not args.resume:
        import shutil

        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = lm_100m()
    model = build_model(cfg)
    print(f"params: {cfg.param_count() / 1e6:.1f}M")
    # near-deterministic latent chain: ~3.5 nats of learnable headroom
    # between the unigram floor and the band-conditional entropy
    data = SyntheticTokens(vocab=cfg.vocab, seq=args.seq,
                           local_batch=args.batch, seed=42,
                           n_states=32, alpha=0.03)
    trainer = Trainer(
        model,
        TrainerConfig(
            train=TrainConfig(
                optimizer=AdamWConfig(lr=3e-3, weight_decay=0.0),
                schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=30,
                                        total_steps=args.steps,
                                        min_ratio=0.5),
            ),
            ckpt_dir=args.ckpt_dir,
            ckpt_every=50,
        ),
        data,
    )
    out = trainer.run(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    first = sum(losses[:5]) / max(len(losses[:5]), 1)
    last = sum(losses[-5:]) / max(len(losses[-5:]), 1)
    print(f"steps={out['final_step']} loss first={first:.3f} "
          f"last={last:.3f} stragglers={out['stragglers']}")
    assert last < first - 1.0, f"model did not learn ({first:.2f}->{last:.2f})"
    print(f"OK: loss dropped by {first - last:.2f} nats (structure learned)")


if __name__ == "__main__":
    main()
