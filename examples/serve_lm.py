"""Serve a small model with batched requests: continuous batching +
paged KV cache + the NFL page table (the paper's technique in the serving
data plane).

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.kv_cache import PagedKVCache, PagedKVConfig
from repro.serve.prefix_cache import composite_key
from repro.serve.scheduler import ContinuousBatcher, Request, ServeConfig


def main():
    cfg = get_config("qwen3-14b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # --- continuous batching over 10 concurrent requests
    batcher = ContinuousBatcher(model, params,
                                ServeConfig(batch_slots=4, max_len=96))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=12)
            for i in range(10)]
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run_until_drained()
    dt = time.perf_counter() - t0
    tok = sum(len(r.output) for r in reqs)
    print(f"continuous batching: {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.0f} tok/s, {batcher.steps} steps)")

    # --- paged KV cache backed by the NFL page table
    kv = PagedKVCache(PagedKVConfig(
        n_pages=256, page_size=8, n_layers=cfg.n_layers,
        kv_heads=cfg.attn.kv_heads, head_dim=cfg.attn.head_dim))
    for sid in (101, 202, 303):
        kv.register_sequence(sid)
        for _ in range(20):
            k = jax.random.normal(jax.random.PRNGKey(sid),
                                  (cfg.n_layers, cfg.attn.kv_heads,
                                   cfg.attn.head_dim))
            kv.append(sid, k, k)
    k, v, n = kv.gather_kv(202)
    print(f"paged KV: gathered [{k.shape}] for seq 202 (len={n})")
    print("NFL page-table stats:", kv.stats()["table"])
    # batched page-table probe: one vectorized lookup for 64 blocks
    pages = kv.lookup_pages(101, 3)
    print("pages of seq 101:", pages.tolist())


if __name__ == "__main__":
    main()
