"""Quickstart: the paper's NFL index end to end in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.nfl import NFL, NFLConfig
from repro.data.datasets import make_dataset
from repro.index import make_index


def main():
    # 1. a hard key distribution (the paper's longlat composite keys)
    keys = make_dataset("longlat", 100_000)
    payloads = np.arange(len(keys), dtype=np.int64)

    # 2. two-stage NFL: Numerical NF transform -> AFLI
    nfl = NFL(NFLConfig())
    nfl.bulkload(keys[::2], payloads[::2])
    print("NF enabled:", nfl.use_flow)
    print("tail conflict degree: "
          f"{nfl.metrics['tail_conflict_original']:.0f} -> "
          f"{nfl.metrics['tail_conflict_transformed']:.0f} (paper Table 3)")

    # 3. batched queries + inserts (paper workloads are batched)
    hits = nfl.lookup_batch(keys[::2][:10_000])
    assert (hits == payloads[::2][:10_000]).all()
    nfl.insert_batch(keys[1::2][:10_000], payloads[1::2][:10_000])
    assert (nfl.lookup_batch(keys[1::2][:10_000])
            == payloads[1::2][:10_000]).all()
    print("index stats:", nfl.stats().as_dict())

    # 4. compare against a classic B-Tree on the same workload
    bt = make_index("btree")
    bt.bulkload(keys[::2], payloads[::2])
    assert (bt.lookup_batch(keys[::2][:1000]) == payloads[::2][:1000]).all()
    print("btree height:", bt.stats()["height"],
          " vs AFLI height:", nfl.stats().height)

    # 5. the fused flat backend: range scans + deletes (DESIGN.md §12).
    # A batch of [lo, hi) ranges is ONE kernel dispatch; deletes are
    # tombstones that vanish from point and range reads immediately.
    # (flow off: ranges then follow plain key order — with a flow they
    # follow the transformed positioning order, see DESIGN.md §12)
    flat = NFL(NFLConfig(backend="flat", force_flow=False))
    flat.bulkload(keys[::2], payloads[::2])
    lo, hi = keys[::2][1000], keys[::2][1040]
    pv, cnt, tot = flat.scan_batch([lo], [hi])
    assert cnt[0] == 40 and (np.sort(pv[0, :40])
                             == payloads[::2][1000:1040]).all()
    ok = flat.delete_batch(keys[::2][1000:1010])
    assert ok.all() and (flat.lookup_batch(keys[::2][1000:1010]) == -1).all()
    pv, cnt, tot = flat.scan_batch([lo], [hi])
    print("range [1000:1040) after deleting 10:", int(cnt[0]), "hits,",
          "dispatch:", flat.index.last_scan_dispatch["path"])
    assert cnt[0] == 30


if __name__ == "__main__":
    main()
