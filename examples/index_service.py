"""NFL as a standalone key-value index service handling the paper's four
workload mixes in request batches — the 'serving' shape of the paper.

  PYTHONPATH=src python examples/index_service.py --dataset facebook
"""

import argparse
import time

import numpy as np

from repro.core.nfl import NFL, NFLConfig
from repro.data.datasets import dataset_names, make_dataset
from repro.data.workloads import MIXES, WorkloadConfig, make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="facebook", choices=dataset_names())
    ap.add_argument("--n-keys", type=int, default=200_000)
    ap.add_argument("--n-ops", type=int, default=100_000)
    ap.add_argument("--batch-size", type=int, default=256)
    args = ap.parse_args()

    keys = make_dataset(args.dataset, args.n_keys)
    for mix in MIXES:
        wl = make_workload(keys, WorkloadConfig(
            mix=mix, n_ops=args.n_ops, batch_size=args.batch_size))
        nfl = NFL(NFLConfig())
        t0 = time.perf_counter()
        nfl.bulkload(wl.load_keys, wl.load_payloads)
        t_load = time.perf_counter() - t0

        t0 = time.perf_counter()
        wrong = 0
        for op, k, v in wl.batches:
            reads = op == 0
            if reads.any():
                res = nfl.lookup_batch(k[reads])
                wrong += int((res != v[reads]).sum())
            if (~reads).any():
                nfl.insert_batch(k[~reads], v[~reads])
        dt = time.perf_counter() - t0
        print(f"{args.dataset:10s} {mix:11s} load={t_load:5.1f}s "
              f"run={dt:6.2f}s {args.n_ops / dt / 1e6:6.3f} Mops/s "
              f"flow={'on' if nfl.use_flow else 'off'} wrong={wrong}")


if __name__ == "__main__":
    main()
