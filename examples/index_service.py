"""NFL as a standalone key-value index service handling the paper's four
workload mixes in request batches — the 'serving' shape of the paper.

  PYTHONPATH=src python examples/index_service.py --dataset facebook

``--backend flat`` serves through the fused Pallas kernels instead of
the paper tree and additionally drives the beyond-paper request types:
batched range scans (one ``pallas_call`` per batch of [lo, hi) ranges,
DESIGN.md §12) and tombstone deletes, mixed into every workload.
"""

import argparse
import time

import numpy as np

from repro.core.nfl import NFL, NFLConfig
from repro.data.datasets import dataset_names, make_dataset
from repro.data.workloads import MIXES, WorkloadConfig, make_workload


def _serve_mix(nfl, wl, *, ranges: bool, n_scans: int = 8):
    """Drive one workload; returns (seconds, wrong).  With ``ranges``,
    every batch additionally answers a small batch of range scans and
    retires a few keys with tombstone deletes."""
    rng = np.random.default_rng(11)
    deleted = set()
    wrong = 0
    t0 = time.perf_counter()
    for op, k, v in wl.batches:
        reads = op == 0
        if reads.any():
            res = nfl.lookup_batch(k[reads])
            exp = np.where(np.isin(k[reads], list(deleted)) if deleted
                           else np.zeros(int(reads.sum()), bool),
                           -1, v[reads])
            wrong += int((res != exp).sum())
        if (~reads).any():
            nfl.insert_batch(k[~reads], v[~reads])
            deleted.difference_update(k[~reads].tolist())
        if ranges:
            lo = rng.choice(wl.load_keys, n_scans)
            hi = lo * (1 + rng.uniform(1e-4, 1e-2, n_scans))
            pv, cnt, tot = nfl.scan_batch(lo, hi)  # one fused dispatch
            dk = rng.choice(wl.load_keys, 2, replace=False)
            ok = nfl.delete_batch(dk)
            deleted.update(dk[ok].tolist())
    return time.perf_counter() - t0, wrong


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="facebook", choices=dataset_names())
    ap.add_argument("--backend", default="afli", choices=["afli", "flat"])
    ap.add_argument("--n-keys", type=int, default=200_000)
    ap.add_argument("--n-ops", type=int, default=100_000)
    ap.add_argument("--batch-size", type=int, default=256)
    args = ap.parse_args()

    keys = make_dataset(args.dataset, args.n_keys)
    flat = args.backend == "flat"
    for mix in MIXES:
        if flat:  # per-mix counters: the dispatch stats are process-global
            from repro.kernels import ops

            ops.reset_fused_lookup_stats()
        wl = make_workload(keys, WorkloadConfig(
            mix=mix, n_ops=args.n_ops, batch_size=args.batch_size))
        nfl = NFL(NFLConfig(backend=args.backend))
        t0 = time.perf_counter()
        nfl.bulkload(wl.load_keys, wl.load_payloads)
        t_load = time.perf_counter() - t0

        dt, wrong = _serve_mix(nfl, wl, ranges=flat)
        line = (f"{args.dataset:10s} {mix:11s} load={t_load:5.1f}s "
                f"run={dt:6.2f}s {args.n_ops / dt / 1e6:6.3f} Mops/s "
                f"flow={'on' if nfl.use_flow else 'off'} wrong={wrong}")
        if flat:
            d = nfl.dispatch_stats()["dispatch"]
            line += (f" scans={d['scan_dispatch_count']}"
                     f" scan_fallbacks={d['scan_fallback_count']}"
                     f" retraces={d['retrace_count']}")
        print(line)


if __name__ == "__main__":
    main()
