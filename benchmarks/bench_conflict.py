"""Paper Table 3: tail conflict degrees, original vs after-NF, load vs run."""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.conflict import dataset_tail_conflict
from repro.core.flow import FlowConfig, transform_keys
from repro.core.train_flow import FlowTrainConfig, train_flow
from repro.data.datasets import make_dataset

from benchmarks.common import ALL_DATASETS


def run(n_keys: int = 100_000, datasets=None) -> List[Tuple]:
    datasets = datasets or ALL_DATASETS
    rows_out = []
    cfg = FlowConfig()
    for ds in datasets:
        keys = make_dataset(ds, n_keys)
        half = len(keys) // 2
        load, extra = keys[:half], keys[half:]
        run_set = np.sort(np.concatenate([load, extra]))

        tail_load = dataset_tail_conflict(load)
        tail_run = dataset_tail_conflict(run_set)
        params, norm, _ = train_flow(load, cfg, FlowTrainConfig(epochs=2))
        z_load = transform_keys(params, norm, load, cfg)
        z_run = transform_keys(params, norm, run_set, cfg)
        tail_load_nf = dataset_tail_conflict(z_load)
        tail_run_nf = dataset_tail_conflict(z_run)
        rows_out.append((ds, tail_load, tail_run, tail_load_nf, tail_run_nf))
        print(f"[table3] {ds:11s} tail(L)={tail_load:6d} tail(R)={tail_run:6d}"
              f"  NF: tail(L)={tail_load_nf:4d} tail(R)={tail_run_nf:4d}")
    return rows_out


def rows(results):
    out = []
    for ds, tl, tr, tln, trn in results:
        out.append((f"table3_tail/{ds}/raw", float(tl), f"run={tr}"))
        out.append((f"table3_tail/{ds}/nf", float(tln), f"run={trn}"))
    return out
