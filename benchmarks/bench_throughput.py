"""Paper Fig. 7: throughput of NFL vs baselines across datasets x mixes."""

from __future__ import annotations

from typing import List

from repro.data.datasets import make_dataset

from benchmarks.common import (DEFAULT_DATASETS, DEFAULT_MIXES, INDEXES,
                               BenchResult, run_workload)


def run(n_keys: int = 100_000, n_ops: int = 30_000,
        datasets=None, mixes=None, indexes=None) -> List[BenchResult]:
    datasets = datasets or DEFAULT_DATASETS
    mixes = mixes or DEFAULT_MIXES
    indexes = indexes or INDEXES
    results = []
    for ds in datasets:
        keys = make_dataset(ds, n_keys)
        for mix in mixes:
            for index in indexes:
                r = run_workload(index, keys, mix, n_ops=n_ops)
                r.dataset = ds
                results.append(r)
                print(f"[fig7] {ds:11s} {mix:11s} {index:6s} "
                      f"{r.throughput_mops:7.3f} Mops/s  p99={r.p99_ns:8.0f}ns"
                      f"  wrong={r.wrong}")
    return results


def rows(results: List[BenchResult]):
    out = []
    for r in results:
        us_per_op = 1.0 / r.throughput_mops if r.throughput_mops else 0.0
        out.append((f"fig7_throughput/{r.dataset}/{r.mix}/{r.index}",
                    us_per_op, f"{r.throughput_mops:.4f}Mops"))
    return out
