"""Sharded key-space serving: P=1 vs P=4 on a forced multi-device host
(DESIGN.md §13).

Measures the scaling axis sharding actually buys (Marcus et al.:
credible throughput claims must report scaling behavior):

* **read window** — balanced batched point lookups, best-of-N wall
  clock.  The workload is sized so the UNSHARDED pools exceed the
  real-TPU per-core VMEM budget (``ops.DEFAULT_VMEM_BUDGET``, 12 MiB)
  and fall off the fused single-dispatch path — since §17 that means
  onto the HBM-streaming tier (``path == "streamed"``, still one
  kernel dispatch, pool tiles double-buffered through VMEM), not the
  host oracle — while each shard's pools still fit fully resident, so
  sharding restores fused serving, which is exactly the mechanism that
  scales on real hardware (per-device pools stay VMEM-resident as the
  keyset grows);
* **steady mixed window** — 80/20 read/insert traffic balanced across
  shards, checked against a dict oracle (wrong must be 0), with the
  per-shard §11 guarantees asserted: zero tier repacks and zero XLA
  retraces per shard inside the measurement window, delta appends and
  delta->run merges included (fold-under-traffic is the serving-state
  bench's and tests/test_sharded.py's territory — a fold's wall-clock
  scales with the keyset, which would turn this throughput window into
  a latency bench).

Run on a forced multi-device host (the flag must land before jax
initializes, so ``run.py --only sharded`` spawns this module as a
subprocess):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.bench_sharded

Emits ``BENCH_sharded.json`` (``--smoke``: small sizes, no artifact).

Scaling caveat, stated in the JSON: the CPU validation platform shares
one physical core pool across the forced devices, so cross-device
kernel *overlap* does not materialize here — the P=4 win comes from the
VMEM-residency mechanism above, and the fan-out/gather plumbing is what
the multi-device placement exercises.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

N_KEYS = 262_144
N_READS = 8_192
N_OPS = 8_192
N_WARMUP = 16_384
BATCH = 2_048
REPEATS = 5
SHARDS = (1, 4)


def run(n_keys: int = N_KEYS, n_reads: int = N_READS, n_ops: int = N_OPS,
        n_warmup: int = N_WARMUP, batch_size: int = BATCH,
        repeats: int = REPEATS, shard_counts=SHARDS,
        vmem_budget: int | None = None, delta_cap: int = 1024,
        out_json: str | None = "BENCH_sharded.json"):
    import numpy as np

    from benchmarks.common import best_s
    from repro.data.datasets import make_dataset
    from repro.core.flat_afli import FlatAFLIConfig
    from repro.core.flow import FlowConfig
    from repro.core.nfl import NFL, NFLConfig
    from repro.core.train_flow import FlowTrainConfig
    from repro.kernels import ops

    if vmem_budget is None:
        # the real-TPU per-core budget, NOT the loose interpret soft
        # cap: the whole point is to measure the pool-residency
        # crossover the way a TPU would see it
        vmem_budget = ops.DEFAULT_VMEM_BUDGET

    import jax

    n_devices = len(jax.devices())
    rng = np.random.default_rng(0)
    keys = make_dataset("lognormal", n_keys + n_warmup + n_ops)
    rng.shuffle(keys)
    build_keys = np.sort(keys[:n_keys])
    insertable = keys[n_keys:]
    payloads = np.arange(n_keys, dtype=np.int64)

    # the write volume is sized to exercise delta appends and delta->
    # run merges in the steady window while keeping the fold
    # reorganization out of it (a fold's wall-clock scales with the
    # keyset, so an in-window fold at this size is a latency bench, not
    # a throughput one — fold-under-traffic is covered by
    # bench_serving_state and tests/test_sharded.py's busy-shard test)
    cfg = FlatAFLIConfig(vmem_budget=vmem_budget, delta_cap=delta_cap)

    result = {
        "workload": {
            "n_keys": n_keys, "n_reads": n_reads, "n_ops": n_ops,
            "n_warmup": n_warmup, "batch_size": batch_size,
            "repeats": repeats, "mix": "read_window + 80/20 steady",
            "dataset": "lognormal", "use_flow": True,
            "vmem_budget": int(vmem_budget), "n_devices": n_devices,
            "shard_counts": list(shard_counts),
        },
        "configs": {},
    }

    for P in shard_counts:
        t0 = time.perf_counter()
        nfl = NFL(NFLConfig(backend="flat", shards=P, force_flow=True,
                            flow=FlowConfig(dim=3),
                            flow_train=FlowTrainConfig(epochs=1),
                            flat_index=cfg))
        nfl.bulkload(build_keys, payloads)
        bulkload_s = time.perf_counter() - t0
        oracle = dict(zip(build_keys.tolist(), payloads.tolist()))

        # ---- balanced per-shard traffic: partition the query and
        # insert pools by routed shard once, then draw equal counts per
        # shard so per-shard batch shapes are deterministic (the §11
        # zero-retrace property is about data movement, not about
        # riding out binomial routing noise)
        shards = nfl.index.shards if P > 1 else [nfl.index]
        if P > 1:
            sid_built = nfl.index._route_points(
                nfl._pkeys(build_keys).astype(np.float32))
            sid_ins = nfl.index._route_points(
                nfl._pkeys(insertable).astype(np.float32))
        else:
            sid_built = np.zeros(len(build_keys), np.int32)
            sid_ins = np.zeros(len(insertable), np.int32)
        built_by = [build_keys[sid_built == s] for s in range(P)]
        ins_by = [list(insertable[sid_ins == s][::-1]) for s in range(P)]

        def read_keys(total):
            per = total // P
            return np.concatenate([
                rng.choice(built_by[s], per, replace=True)
                for s in range(P)])

        def insert_keys(total):
            per = total // P
            return np.array([ins_by[s].pop() for s in range(P)
                             for _ in range(per)])

        # ---------------------------------------------------- read window
        q = read_keys(n_reads)
        expect = np.array([oracle[k] for k in q.tolist()])
        res = nfl.lookup_batch(q)
        read_wrong = int((res != expect).sum())
        # the shared warm/measure/compile-count protocol (common.best_s)
        best, warm_c, meas_c = best_s(lambda: nfl.lookup_batch(q),
                                      repeats)
        shard0 = shards[0]
        read = {
            "wall_s": best,
            "throughput_mops": n_reads / best / 1e6,
            "us_per_query": best / n_reads * 1e6,
            "path": shard0.last_dispatch.get("path"),
            "pool_bytes_per_shard": shard0.last_dispatch.get("pool_bytes"),
            "stream_tile": shard0.last_dispatch.get("stream_tile"),
            "tiles_streamed": shard0.last_dispatch.get("tiles_streamed"),
            "compiles_warmup": warm_c,
            "compiles_measure": meas_c,
            "wrong": read_wrong,
        }

        # ------------------------------------------------- steady window
        def drive(n, measure_lat=False):
            """One 80/20 window; per-batch serving latencies exclude the
            dict-oracle bookkeeping (the serving window is what is
            measured, as in the other serving benches)."""
            wrong = 0
            lat = []
            n_read_b = int(batch_size * 0.8)
            n_ins_b = batch_size - n_read_b
            for _ in range(n // batch_size):
                rk = read_keys(n_read_b)
                ik = insert_keys(n_ins_b)
                iv = np.arange(len(ik)) + 50_000_000
                t0 = time.perf_counter()
                res = nfl.lookup_batch(rk)
                t1 = time.perf_counter()
                nfl.insert_batch(ik, iv)
                t2 = time.perf_counter()
                exp = np.array([oracle[k] for k in rk.tolist()])
                wrong += int((res != exp).sum())
                oracle.update(zip(ik.tolist(), iv.tolist()))
                if measure_lat:
                    lat.append((t1 - t0, t2 - t1))
            return wrong, lat

        warm_wrong, _ = drive(n_warmup)
        # reset every counter the steady gates read
        ops.reset_fused_lookup_stats()
        for s in shards:
            s._serving.reset_stats()
        rebuilds0 = [s.n_rebuilds for s in shards]
        host_probes0 = sum(s.n_host_tier_probes for s in shards)

        steady_wrong, lat = drive(n_ops, measure_lat=True)
        run_s = float(sum(r + w for r, w in lat))  # serving time only
        stats = ops.fused_lookup_stats()
        per_shard = []
        for i, s in enumerate(shards):
            sv = s._serving.stats()
            per_shard.append({
                "tier_repacks": sv["tier_repacks"],
                "tier_uploads": sv["tier_uploads"],
                "rebuilds_in_window": s.n_rebuilds - rebuilds0[i],
                "fold_active_at_end": s._fold is not None,
            })
        read_lat = np.array([l[0] for l in lat]) / (batch_size * 0.8)
        steady = {
            "n_ops": n_ops, "run_s": run_s,
            "throughput_mops": n_ops / run_s / 1e6,
            "wrong": steady_wrong, "warmup_wrong": warm_wrong,
            "retrace_count": stats["retrace_count"],
            "read_p50_us": float(np.percentile(read_lat, 50) * 1e6),
            "read_p99_us": float(np.percentile(read_lat, 99) * 1e6),
            "host_tier_probes_in_window":
                sum(s.n_host_tier_probes for s in shards) - host_probes0,
            "per_shard": per_shard,
        }

        entry = {"bulkload_s": bulkload_s, "read": read, "steady": steady}
        if P > 1:
            entry["router"] = {
                k: (list(v) if isinstance(v, list) else v)
                for k, v in nfl.index._router.items()}
        result["configs"][f"P{P}"] = entry
        print(f"P={P}: bulkload {bulkload_s:.1f}s | read "
              f"{read['throughput_mops']:.3f} Mops/s ({read['path']}, "
              f"{read['pool_bytes_per_shard']/2**20:.1f} MiB/shard) | "
              f"steady {steady['throughput_mops']:.4f} Mops/s, "
              f"wrong={steady_wrong}, retraces={stats['retrace_count']}, "
              f"repacks={[p['tier_repacks'] for p in per_shard]}, "
            f"folds={[p['rebuilds_in_window'] for p in per_shard]}")

        # hard gates (mirrors verify.sh's wrong>0 rule + the §11/§13
        # zero-retrace/zero-repack acceptance)
        assert read_wrong == 0 and steady_wrong == 0 and warm_wrong == 0, \
            f"P={P}: wrong answers in serving windows"
        assert stats["retrace_count"] == 0, \
            f"P={P}: {stats['retrace_count']} retraces in steady window"
        assert all(p["tier_repacks"] == 0 for p in per_shard), \
            f"P={P}: tier repacks in steady window"
        # §17 regression gate: every dispatch route (fused when the
        # pools fit, streamed when they don't) probes the write tiers
        # in-kernel — a host-side tier probe in the steady window means
        # a read left the kernel path (the pre-§17 P=1 behavior: 4
        # oracle read batches x 1 host probe each)
        assert steady["host_tier_probes_in_window"] == 0, \
            (f"P={P}: {steady['host_tier_probes_in_window']} host tier "
             "probes in steady window — reads left the kernel path")

    ps = [f"P{p}" for p in shard_counts]
    if len(ps) >= 2:
        r0 = result["configs"][ps[0]]["read"]
        r1 = result["configs"][ps[-1]]["read"]
        s0 = result["configs"][ps[0]]["steady"]
        s1 = result["configs"][ps[-1]]["steady"]
        result["scaling"] = {
            "read_speedup": r1["throughput_mops"] / r0["throughput_mops"],
            "steady_speedup":
                s1["throughput_mops"] / s0["throughput_mops"],
            "p_lo_path": r0["path"], "p_hi_path": r1["path"],
            "mechanism": "per-shard pools fit the per-device VMEM "
                         "budget and serve fully resident (fused); the "
                         "unsharded pools do not and stream tiles "
                         "through VMEM (streamed, §17)",
        }
        print(f"scaling {ps[0]} -> {ps[-1]}: read "
              f"{result['scaling']['read_speedup']:.2f}x "
              f"({r0['path']} -> {r1['path']}), steady "
              f"{result['scaling']['steady_speedup']:.2f}x")

    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out_json}")
    return result


def rows(result):
    out = []
    for name, cfg in result["configs"].items():
        out.append((f"sharded_read_{name}", cfg["read"]["us_per_query"],
                    f"{cfg['read']['throughput_mops']:.3f}Mops_"
                    f"{cfg['read']['path']}"))
        out.append((f"sharded_steady_{name}",
                    cfg["steady"]["run_s"] / cfg["steady"]["n_ops"] * 1e6,
                    f"wrong={cfg['steady']['wrong']}_retrace="
                    f"{cfg['steady']['retrace_count']}"))
    if "scaling" in result:
        out.append(("sharded_read_speedup", 0.0,
                    f"{result['scaling']['read_speedup']:.2f}x"))
    return out


def run_at_workload(w: dict, out_json: str | None = None):
    """Re-run at a recorded baseline's workload block (``--compare``)."""
    return run(
        n_keys=int(w.get("n_keys", N_KEYS)),
        n_reads=int(w.get("n_reads", N_READS)),
        n_ops=int(w.get("n_ops", N_OPS)),
        n_warmup=int(w.get("n_warmup", N_WARMUP)),
        batch_size=int(w.get("batch_size", BATCH)),
        repeats=int(w.get("repeats", REPEATS)),
        shard_counts=tuple(w.get("shard_counts", SHARDS)),
        vmem_budget=w.get("vmem_budget"), out_json=out_json)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes, no JSON artifact")
    ap.add_argument("--n-keys", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="forced host device count (set before jax init; "
                         "default: max of the shard counts run)")
    ap.add_argument("--compare-rerun", metavar="BASELINE_JSON",
                    help="re-run at this baseline's recorded workload "
                         "(and its device topology) instead of the "
                         "default workload")
    ap.add_argument("--out", default=None,
                    help="result JSON path (with --compare-rerun: where "
                         "the fresh result lands for the caller to diff)")
    args = ap.parse_args()

    base_w = None
    if args.compare_rerun:
        with open(args.compare_rerun) as f:
            base_w = json.load(f).get("workload", {})
    devices = args.devices
    if devices is None:
        counts = (base_w or {}).get("shard_counts", SHARDS)
        devices = max(int(p) for p in counts)

    # must land before jax initializes — this module delays every
    # jax-importing import into run() for exactly this reason
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{devices}").strip()

    if base_w is not None:
        run_at_workload(base_w, out_json=args.out)
    elif args.smoke:
        run(n_keys=args.n_keys or 16_384, n_reads=2_048, n_ops=2_048,
            n_warmup=4_096, batch_size=1_024, repeats=2, delta_cap=256,
            out_json=args.out)
    else:
        run(**{**({"n_keys": args.n_keys} if args.n_keys else {}),
               **({"out_json": args.out} if args.out else {})})


if __name__ == "__main__":
    main()
