"""Mixed read/insert serving on the flat backend (DESIGN.md §10).

NFL's headline claim is highest throughput *and lowest tail latency*
under read-write workloads.  This bench drives the fused flat backend
through read/insert mixes (95/5, 80/20, 50/50) in fixed-size request
batches and records the per-op latency distribution — p50/p99/p999/max —
plus the write-path telemetry that the tiered design is supposed to
move:

* ``host_tier_probes`` must stay 0 while the delta/run tiers fit the
  kernel pool budget (every mixed batch is ONE ``pallas_call``, no host
  delta round trip);
* no single ``insert_batch`` call may pay a full O(n) rebuild stall —
  the incremental fold bounds it, reported as ``max_insert_call_s`` and
  the p999/p50 ratio at the 80/20 mix;
* results are cross-checked against a dict oracle (last-write-wins).

Emits machine-readable ``BENCH_mixed_workload.json``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.flat_afli import FlatAFLIConfig
from repro.core.flow import FlowConfig
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.data.datasets import make_dataset

DEFAULT_OUT = "BENCH_mixed_workload.json"
MIXES = (("95/5", 0.05), ("80/20", 0.20), ("50/50", 0.50))


def _percentiles(lat_ns: np.ndarray):
    return {
        "p50_ns": float(np.percentile(lat_ns, 50)),
        "p99_ns": float(np.percentile(lat_ns, 99)),
        "p999_ns": float(np.percentile(lat_ns, 99.9)),
        "max_ns": float(lat_ns.max()),
    }


def _run_mix(keys: np.ndarray, insert_pool: np.ndarray, write_frac: float,
             n_ops: int, batch_size: int, seed: int,
             n_warmup: int | None = None):
    """One mix on a freshly bulkloaded index; returns the result dict.

    The run is split into a **warmup window** (compile priming: every
    read-batch bucket, the delta growth ladder, and ideally a first
    fold) and the **measurement window**, so p99/p999 reflect
    steady-state serving rather than XLA trace time; the compile count
    per phase (serving dispatches that grew a jit cache,
    ``ops.fused_lookup_stats``) is reported alongside."""
    from repro.kernels import ops as kops

    pv = np.arange(len(keys), dtype=np.int64)
    # tight tier bounds so delta merges AND incremental folds actually
    # fire inside the measured window (the stall they bound is the test)
    nfl = NFL(NFLConfig(
        flow=FlowConfig(dim=3), flow_train=FlowTrainConfig(epochs=1),
        backend="flat",
        flat_index=FlatAFLIConfig(rebuild_frac=0.005, delta_cap=256,
                                  fold_step_keys=8192)))
    t0 = time.perf_counter()
    nfl.bulkload(keys, pv)
    t_load = time.perf_counter() - t0

    oracle = {k: p for k, p in zip(keys, pv)}
    rng = np.random.default_rng(seed)
    if n_warmup is None:
        n_warmup = max(batch_size * 8, n_ops // 4)
    nfl.index.n_host_tier_probes = 0

    state = {"next_ins": 0, "high_water": 0, "ops_done": 0}

    def drive(n, lat, read_lat, ins_lat, ins_call_s):
        """Drive ``n`` ops of the mix; returns (wrong, tier_path)."""
        wrong = 0
        serve_tier_path = None  # routing of the SERVING dispatches (the
        #                         fold's internal verify lookups also
        #                         touch last_dispatch, so sample right
        #                         after serving)
        done = 0
        while done < n:
            is_write = rng.random(batch_size) < write_frac
            n_w = int(is_write.sum())
            n_r = batch_size - n_w
            if n_r:
                # reads target bulkloaded AND already-inserted keys, so
                # the dict-oracle check validates the write tiers
                q = rng.choice(keys, n_r)
                if state["high_water"]:
                    tiered = rng.random(n_r) < 0.5
                    q[tiered] = rng.choice(
                        insert_pool[:state["high_water"]],
                        int(tiered.sum()))
            else:
                q = None
            if n_w and state["next_ins"] + n_w > len(insert_pool):
                state["next_ins"] = 0  # wrap: re-inserts exercise
                #                        last-write-wins
            ins_k = insert_pool[state["next_ins"]:state["next_ins"] + n_w]
            ins_v = (np.arange(n_w, dtype=np.int64) + 1_000_000_000
                     + state["ops_done"] + done)
            state["next_ins"] += n_w
            # serving time only — dict-oracle bookkeeping stays OUTSIDE
            # every timed window so the p50/p999 gate measures the
            # index, not the benchmark's own Python loops
            t_read = 0.0
            res = None
            if q is not None and len(q):
                t0 = time.perf_counter()
                res = nfl.lookup_batch(q)
                t_read = time.perf_counter() - t0
                read_lat.append(t_read / len(q))
                serve_tier_path = nfl.index.last_dispatch.get("tier_path")
            t_ins = 0.0
            if n_w:
                t0 = time.perf_counter()
                nfl.insert_batch(ins_k, ins_v)
                t_ins = time.perf_counter() - t0
                ins_call_s.append(t_ins)
                ins_lat.append(t_ins / n_w)
            lat.append((t_read + t_ins) / batch_size)
            if res is not None:
                exp = np.array([oracle.get(k, -1) for k in q])
                wrong += int((res != exp).sum())
            if n_w:
                for k, v in zip(ins_k, ins_v):
                    oracle[k] = v
                state["high_water"] = max(state["high_water"],
                                          state["next_ins"])
            done += batch_size
        state["ops_done"] += done
        return wrong, serve_tier_path

    # ---- warmup window (compile priming; latencies discarded)
    kops.reset_fused_lookup_stats()
    t0 = time.perf_counter()
    warm_wrong, _ = drive(n_warmup, [], [], [], [])
    t_warm = time.perf_counter() - t0
    warm_compiles = kops.fused_lookup_stats()["retrace_count"]

    # ---- measurement window (steady state)
    kops.reset_fused_lookup_stats()
    nfl.index.n_host_tier_probes = 0
    lat, read_lat, ins_lat, ins_call_s = [], [], [], []
    t_run0 = time.perf_counter()
    wrong, serve_tier_path = drive(n_ops, lat, read_lat, ins_lat,
                                   ins_call_s)
    t_run = time.perf_counter() - t_run0
    wrong += warm_wrong  # warmup correctness failures must not vanish
    meas_compiles = kops.fused_lookup_stats()["retrace_count"]

    st = nfl.stats()  # end-of-workload state, before the calibration below
    # self-calibrating stall baseline: the synchronous full Modelling
    # this index would pay without the incremental fold (completes any
    # in-flight fold, then folds the leftovers end to end)
    t0 = time.perf_counter()
    nfl.index.rebuild()
    t_full_rebuild = time.perf_counter() - t0

    lat_ns = np.asarray(lat) * 1e9
    out = {
        "write_frac": write_frac,
        "n_ops": n_ops,
        "n_warmup": n_warmup,
        "bulkload_s": t_load,
        "warmup_s": t_warm,
        "run_s": t_run,
        "throughput_mops": n_ops / t_run / 1e6,
        "compiles_warmup": warm_compiles,
        "compiles_measure": meas_compiles,
        **_percentiles(lat_ns),
        "read": _percentiles(np.asarray(read_lat) * 1e9),
        "insert": _percentiles(np.asarray(ins_lat) * 1e9)
        if ins_lat else {},
        "max_insert_call_s": float(max(ins_call_s)) if ins_call_s else 0.0,
        "full_rebuild_s": t_full_rebuild,
        "wrong": wrong,
        "host_tier_probes": int(st["n_host_tier_probes"]),
        "n_rebuilds": int(st["n_rebuilds"]),
        "fold_active_at_end": bool(st["fold_active"]),
        "delta_len": int(st["delta_len"]),
        "run_len": int(st["run_len"]),
        "tier_path": serve_tier_path,
    }
    out["p999_over_p50"] = out["p999_ns"] / max(out["p50_ns"], 1.0)
    out["read_p99_over_p50"] = (out["read"]["p99_ns"]
                                / max(out["read"]["p50_ns"], 1.0))
    return out


def run(n_keys: int = 65_536, n_ops: int = 12_288, batch_size: int = 256,
        out_json: str = DEFAULT_OUT, n_warmup: int | None = None):
    all_keys = make_dataset("lognormal", int(n_keys * 1.5))
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(all_keys))
    keys = np.ascontiguousarray(all_keys[perm[:n_keys]])
    insert_pool = np.ascontiguousarray(all_keys[perm[n_keys:]])

    results = {"workload": {"n_keys": int(len(keys)),
                            "n_insertable": int(len(insert_pool)),
                            "n_ops": n_ops, "batch_size": batch_size,
                            "dataset": "lognormal"},
               "mixes": {}}
    for mix_no, (name, frac) in enumerate(MIXES):
        r = _run_mix(keys, insert_pool, frac, n_ops, batch_size,
                     seed=1000 + mix_no, n_warmup=n_warmup)
        results["mixes"][name] = r
        print(f"[mixed {name}] {r['throughput_mops']*1e3:.1f} kops/s "
              f"p50={r['p50_ns']/1e3:.1f}us p99={r['p99_ns']/1e3:.1f}us "
              f"p999={r['p999_ns']/1e3:.1f}us (x{r['p999_over_p50']:.1f}) "
              f"compiles={r['compiles_warmup']}+{r['compiles_measure']} "
              f"wrong={r['wrong']} host_probes={r['host_tier_probes']} "
              f"rebuilds={r['n_rebuilds']}")
        if r["wrong"]:
            raise AssertionError(f"mixed workload {name}: {r['wrong']} "
                                 "lookups diverged from the dict oracle")
    eighty = results["mixes"]["80/20"]
    # the gate is only meaningful if the incremental fold actually engaged
    # in the gated window (a completed fold or one still in flight).
    # With §11 zero-repack serving the combined p50 is dominated by the
    # (now ~100x faster) steady-state batches, so the old combined
    # p999/p50 ratio no longer separates "stall" from "fast p50"; the
    # gate is instead calibrated against the measured synchronous
    # alternatives: no insert call may out-stall the full reorganization
    # it replaces (the larger of the initial bulkload and the end-state
    # synchronous rebuild), and the read tail must stay within the
    # ISSUE-3 steady-state bound.
    results["no_full_rebuild_stall"] = (
        eighty["max_insert_call_s"]
        < max(eighty["full_rebuild_s"], eighty["bulkload_s"])
        and eighty["read_p99_over_p50"] <= 10.0
        and (eighty["n_rebuilds"] >= 1 or eighty["fold_active_at_end"]))
    results["zero_host_probes"] = all(
        m["host_tier_probes"] == 0 for m in results["mixes"].values())
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    out = []
    for name, r in results["mixes"].items():
        out.append((f"perf_mixed_workload/{name.replace('/', '_')}",
                    r["p50_ns"] / 1e3,
                    f"p999_over_p50={r['p999_over_p50']:.1f};"
                    f"host_probes={r['host_tier_probes']};"
                    f"rebuilds={r['n_rebuilds']}"))
    return out
