"""Shared benchmark harness: paper workloads against any index."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.afli import AFLI
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.data.workloads import WorkloadConfig, Workload, make_workload
from repro.index import make_index

DEFAULT_DATASETS = ["longlat", "lognormal", "ycsb", "facebook"]


def best_s(fn: Callable, repeats: int):
    """(best wall seconds, warmup compiles, measurement compiles).

    The warmup call primes the jit/pallas caches outside the timed
    region; compile counts per phase come from the serving jit-cache
    growth (``ops.serving_cache_size``) so steady-state measurements can
    assert zero mid-measurement compiles instead of assuming them."""
    from repro.kernels import ops

    c0 = ops.serving_cache_size()
    fn()  # warm the jit/pallas caches outside the timed region
    warm_compiles = ops.serving_cache_size() - c0
    best = float("inf")
    c1 = ops.serving_cache_size()
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best, warm_compiles, ops.serving_cache_size() - c1
ALL_DATASETS = ["longitudes", "longlat", "lognormal", "ycsb", "amazon",
                "facebook", "wikipedia"]
DEFAULT_MIXES = ["read_only", "read_heavy", "write_heavy", "write_only"]


class FlatNFLAdapter:
    """Beyond-paper serving path: the fused single-dispatch Pallas kernel —
    NF forward + multi-level FlatAFLI traversal + in-kernel write-tier
    probe in one ``pallas_call`` per request batch (DESIGN.md §9/§10) —
    with tiered log-structured inserts (last-write-wins identity) and
    incremental folds instead of synchronous O(n) rebuilds.
    §Perf hillclimb 3."""

    def __init__(self, dim: int = 3):
        from repro.core.flow import FlowConfig

        self.nfl = NFL(NFLConfig(flow=FlowConfig(dim=dim),
                                 flow_train=FlowTrainConfig(epochs=1),
                                 backend="flat"))

    @property
    def idx(self):
        return self.nfl.index

    def bulkload(self, keys, payloads):
        self.nfl.bulkload(keys, payloads)

    def lookup_batch(self, keys):
        return self.nfl.lookup_batch(keys)

    def insert_batch(self, keys, payloads):
        self.nfl.insert_batch(keys, payloads)

    def update_batch(self, keys, payloads):
        return self.nfl.update_batch(keys, payloads)

    def delete_batch(self, keys):
        return self.nfl.delete_batch(keys)

    def scan_batch(self, lo_keys, hi_keys, cap=None):
        return self.nfl.scan_batch(lo_keys, hi_keys, cap=cap)

    def size_bytes(self):
        a = self.nfl.index.arrays
        if a is None:
            return 0
        return int(sum(x.size * x.dtype.itemsize for x in a))

    def stats(self):
        return self.nfl.index.stats()


class ShardedNFLAdapter(FlatNFLAdapter):
    """Key-space-sharded flat serving (DESIGN.md §13): P FlatAFLI
    shards, one device each, behind the same batched API — the router
    bins each batch by flow-CDF boundaries, fans out to the per-shard
    fused kernels, and gathers back to input order."""

    def __init__(self, shards: int = 2, dim: int = 3,
                 force_flow=None):
        from repro.core.flow import FlowConfig

        self.nfl = NFL(NFLConfig(flow=FlowConfig(dim=dim),
                                 flow_train=FlowTrainConfig(epochs=1),
                                 backend="flat", shards=shards,
                                 force_flow=force_flow))

    def size_bytes(self):
        # shards=1 degrades to a plain FlatAFLI inside NFL
        shards = getattr(self.nfl.index, "shards", [self.nfl.index])
        total = 0
        for shard in shards:
            if shard.arrays is not None:
                total += int(sum(x.size * x.dtype.itemsize
                                 for x in shard.arrays))
        return total


class AFLIAdapter:
    """Standalone AFLI (no flow) behind the batched benchmark API."""

    def __init__(self):
        self.idx = AFLI()

    def bulkload(self, keys, payloads):
        self.idx.bulkload(keys, payloads)

    def lookup_batch(self, keys):
        out = np.empty(len(keys), np.int64)
        lk = self.idx.lookup
        for i, k in enumerate(keys):
            r = lk(float(k))
            out[i] = -1 if r is None else r
        return out

    def insert_batch(self, keys, payloads):
        ins = self.idx.insert
        for k, v in zip(keys, payloads):
            ins(float(k), int(v))

    def size_bytes(self):
        return self.idx.stats().size_bytes

    def stats(self):
        return self.idx.stats().as_dict()


class BaselineAdapter:
    def __init__(self, name):
        self.idx = make_index(name)

    def bulkload(self, keys, payloads):
        self.idx.bulkload(keys, payloads)

    def lookup_batch(self, keys):
        return self.idx.lookup_batch(keys)

    def insert_batch(self, keys, payloads):
        self.idx.insert_batch(keys, payloads)

    def size_bytes(self):
        return self.idx.size_bytes()

    def stats(self):
        return self.idx.stats()


def make_bench_index(name: str):
    if name == "nfl":
        # paper-faithful: 2 input dims, 2 hidden, 2 layers (paper §4.1.3)
        return NFL(NFLConfig(flow_train=FlowTrainConfig(epochs=1)))
    if name == "nfl4":
        # beyond-paper: 4-dim feature expansion resolves multi-scale key
        # distributions the 2-dim flow cannot (EXPERIMENTS.md §Perf)
        from repro.core.flow import FlowConfig

        return NFL(NFLConfig(flow=FlowConfig(dim=4),
                             flow_train=FlowTrainConfig(epochs=1)))
    if name == "nfl_flat":
        return FlatNFLAdapter()
    if name.startswith("nfl_sharded"):
        # "nfl_sharded" -> 2 shards; "nfl_shardedP" -> P shards
        suffix = name[len("nfl_sharded"):]
        return ShardedNFLAdapter(shards=int(suffix) if suffix else 2)
    if name == "afli":
        return AFLIAdapter()
    return BaselineAdapter(name)


INDEXES = ["nfl", "nfl4", "nfl_flat", "afli", "lipp", "alex", "pgm", "btree"]


@dataclasses.dataclass
class BenchResult:
    dataset: str
    mix: str
    index: str
    n_keys: int
    n_ops: int
    bulkload_s: float
    run_s: float
    throughput_mops: float
    p50_ns: float
    p99_ns: float
    p9999_ns: float
    max_ns: float
    wrong: int
    size_bytes: int
    extra: Dict = dataclasses.field(default_factory=dict)


def run_workload(index_name: str, keys: np.ndarray, mix: str,
                 n_ops: int = 30_000, batch_size: int = 256,
                 seed: int = 0) -> BenchResult:
    wl = make_workload(keys, WorkloadConfig(mix=mix, n_ops=n_ops,
                                            batch_size=batch_size, seed=seed))
    idx = make_bench_index(index_name)
    t0 = time.perf_counter()
    idx.bulkload(wl.load_keys, wl.load_payloads)
    t_load = time.perf_counter() - t0

    # warmup: compile the batched-transform shape buckets outside the
    # timed region (reads only; steady-state is what the paper reports)
    warm = wl.load_keys[: min(256, len(wl.load_keys))]
    idx.lookup_batch(warm)
    idx.lookup_batch(warm[:37])

    wrong = 0
    lat = []
    t_run0 = time.perf_counter()
    for op, k, v in wl.batches:
        t0 = time.perf_counter()
        reads = op == 0
        if reads.any():
            res = idx.lookup_batch(k[reads])
            wrong += int((res != v[reads]).sum())
        if (~reads).any():
            idx.insert_batch(k[~reads], v[~reads])
        lat.append((time.perf_counter() - t0) / len(op))
    t_run = time.perf_counter() - t_run0

    lat_ns = np.asarray(lat) * 1e9
    extra = {}
    if isinstance(idx, NFL):
        extra = {"use_flow": idx.use_flow, **idx.metrics}
    return BenchResult(
        dataset="?", mix=mix, index=index_name, n_keys=len(keys),
        n_ops=n_ops, bulkload_s=t_load, run_s=t_run,
        throughput_mops=n_ops / t_run / 1e6,
        p50_ns=float(np.percentile(lat_ns, 50)),
        p99_ns=float(np.percentile(lat_ns, 99)),
        p9999_ns=float(np.percentile(lat_ns, 99.99)),
        max_ns=float(lat_ns.max()),
        wrong=wrong,
        size_bytes=int(idx.size_bytes() if hasattr(idx, "size_bytes")
                       else idx.stats().size_bytes),
        extra=extra,
    )
