"""Paper Fig. 8 (P99) and Fig. 9 (P99.99/max) per-op tail latency.

The paper measures per-batch latency divided by batch size (§4.3); the
harness already records those percentiles during the throughput runs, so
this module re-runs the representative workloads at higher op counts for a
stable tail.
"""

from __future__ import annotations

from typing import List

from repro.data.datasets import make_dataset

from benchmarks.common import INDEXES, BenchResult, run_workload

REPRESENTATIVE = ["longlat", "facebook"]  # the paper's high-conflict pair


def run(n_keys: int = 100_000, n_ops: int = 60_000,
        mixes=("read_only", "write_heavy"), indexes=None) -> List[BenchResult]:
    indexes = indexes or INDEXES
    results = []
    for ds in REPRESENTATIVE:
        keys = make_dataset(ds, n_keys)
        for mix in mixes:
            for index in indexes:
                r = run_workload(index, keys, mix, n_ops=n_ops)
                r.dataset = ds
                results.append(r)
                print(f"[fig8/9] {ds:9s} {mix:11s} {index:6s} "
                      f"p99={r.p99_ns:9.0f}ns p99.99={r.p9999_ns:9.0f}ns "
                      f"max={r.max_ns:10.0f}ns")
    return results


def rows(results: List[BenchResult]):
    out = []
    for r in results:
        out.append((f"fig8_p99/{r.dataset}/{r.mix}/{r.index}",
                    r.p99_ns / 1e3, f"p9999={r.p9999_ns:.0f}ns"))
        out.append((f"fig9_max/{r.dataset}/{r.mix}/{r.index}",
                    r.max_ns / 1e3, f"p50={r.p50_ns:.0f}ns"))
    return out
