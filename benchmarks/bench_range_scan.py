"""Fused tier-merged range scans vs the host oracle and the naive loop.

The workload is the ISSUE-4 acceptance shape: >= 64k flow-positioned
keys, 4k ``[lo, hi)`` range queries.  Three variants over identical
inputs:

* ``per_key_loop`` — the only pre-§12 way to answer a range query: a
  host-side loop that enumerates each range's member keys (host
  ``searchsorted`` over a sorted key snapshot) and resolves them through
  batched *point* lookups, one serving call per range;
* ``host_oracle``  — the vectorized host fallback path
  (``nf_forward_pallas`` endpoint transform + ``_range_scan_host``),
  the bit-exactness reference;
* ``fused``        — ONE ``pallas_call`` per query batch: in-kernel NF
  forward on both endpoints + lower-bound location + three-way tier
  merge (``kernels/range_scan``).

A steady-state phase then mixes range traffic into the 80/20 serving
loop (reads / inserts / deletes / scans) and asserts the §11/§12
zero-retrace, zero-repack properties with the scan path live.  Every
scan is cross-checked against a positioning-key-order dict oracle;
``wrong`` must be 0.  Emits machine-readable ``BENCH_range_scan.json``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.feature import expand_features
from repro.core.flat_afli import FlatAFLIConfig
from repro.core.flow import FlowConfig
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.data.datasets import make_dataset
from repro.kernels import ops

from benchmarks.common import best_s as _best_s

DEFAULT_OUT = "BENCH_range_scan.json"


def _z32(nfl, keys):
    """Serve-path positioning keys (kernel NF path, f32) for oracles."""
    keys = np.asarray(keys, dtype=np.float64)
    if not nfl.use_flow:
        return keys.astype(np.float32)
    return nfl._transform(nfl.flow_params, nfl.normalizer,
                          keys).astype(np.float32)


def _steady_state(nfl, keys, insert_pool, *, n_ops: int, n_warmup: int,
                  batch_size: int, cap: int, seed: int = 7):
    """Mix range scans into the 80/20 serving loop: 70% point reads,
    15% inserts, 5% deletes, 10% range scans per batch.  Warmup primes
    every shape bucket, then the telemetry is zeroed and the measured
    window must show zero retraces and zero repacks with the scan path
    live (§12 acceptance)."""
    rng = np.random.default_rng(seed)
    oracle = dict(zip(keys.tolist(),
                      np.arange(len(keys), dtype=np.int64).tolist()))
    zmap = dict(zip(keys.tolist(), _z32(nfl, keys).tolist()))
    next_ins = 0
    wrong = 0
    scan_lat, read_lat = [], []

    def one_window(n_ops):
        nonlocal next_ins, wrong
        done = 0
        t0_run = time.perf_counter()
        while done < n_ops:
            n_scan = max(batch_size // 10, 1)
            n_del = max(batch_size // 20, 1)
            n_ins = max(int(batch_size * 0.15), 1)
            n_read = batch_size - n_scan - n_del - n_ins
            live = np.fromiter(oracle.keys(), np.float64, len(oracle))
            # point reads
            q = rng.choice(live, n_read)
            t0 = time.perf_counter()
            res = nfl.lookup_batch(q)
            read_lat.append((time.perf_counter() - t0) / n_read)
            exp = np.array([oracle.get(k, -1) for k in q])
            wrong += int((res != exp).sum())
            # inserts (fresh keys; payloads disjoint from the build's)
            if next_ins + n_ins > len(insert_pool):
                next_ins = 0
            ins_k = insert_pool[next_ins:next_ins + n_ins]
            ins_v = np.arange(n_ins, dtype=np.int64) + 1_000_000_000 + done
            next_ins += n_ins
            nfl.insert_batch(ins_k, ins_v)
            for k, v, z in zip(ins_k.tolist(), ins_v.tolist(),
                               _z32(nfl, ins_k).tolist()):
                oracle[k] = v
                zmap[k] = z
            # deletes of live keys
            dk = rng.choice(live, min(n_del, len(live)), replace=False)
            nfl.delete_batch(dk)
            for k in dk.tolist():
                oracle.pop(k, None)
            # range scans around live keys (spans well under scan_cap).
            # Endpoints are perturbed OFF the stored keys: a fold
            # re-keys serve-path-divergent identities at their in-kernel
            # z (§8 shadows, 1 ulp from the build z), so an endpoint
            # exactly equal to a stored key's build z is ambiguous by
            # construction — strictly-between endpoints are not
            lo = rng.choice(live, n_scan) * (1.0
                                             + rng.uniform(1e-7, 1e-5,
                                                           n_scan))
            hi = lo * (1.0 + rng.uniform(1e-4, 3e-3, n_scan))
            t0 = time.perf_counter()
            pv, cnt, tot = nfl.scan_batch(lo, hi, cap=cap)
            scan_lat.append((time.perf_counter() - t0) / n_scan)
            zlo, zhi = _z32(nfl, lo), _z32(nfl, hi)
            zs = np.fromiter((zmap[k] for k in oracle), np.float32,
                             len(oracle))
            pvs = np.fromiter(oracle.values(), np.int64, len(oracle))
            for i in range(n_scan):
                if tot[i] > cap:
                    continue  # truncated: counted via dispatch stats
                exp = np.sort(pvs[(zs >= zlo[i]) & (zs < zhi[i])])
                got = np.sort(pv[i, :cnt[i]])
                wrong += int(not np.array_equal(got, exp))
            done += batch_size
        return time.perf_counter() - t0_run

    one_window(n_warmup)
    ops.reset_fused_lookup_stats()
    nfl.index._serving.reset_stats()
    nfl.index.n_host_tier_probes = 0
    nfl.index.n_host_scans = 0
    rebuilds_before = nfl.index.n_rebuilds
    wrong = 0
    scan_lat.clear()
    read_lat.clear()
    run_s = one_window(n_ops)
    disp = nfl.dispatch_stats()
    st = nfl.stats()
    out = {
        "n_ops": n_ops,
        "run_s": run_s,
        "wrong": wrong,
        "retrace_count": disp["dispatch"]["retrace_count"],
        "scan_dispatches": disp["dispatch"]["scan_dispatch_count"],
        "scan_fallbacks": disp["dispatch"]["scan_fallback_count"],
        "scan_truncations": disp["dispatch"]["scan_trunc_count"],
        "host_scans": disp["host_scans"],
        "host_tier_probes": disp["host_tier_probes"],
        "tier_repacks": disp["serving"]["tier_repacks"],
        "tier_uploads": disp["serving"]["tier_uploads"],
        "n_rebuilds_in_window": int(st["n_rebuilds"]) - rebuilds_before,
        "fold_active_at_end": bool(st["fold_active"]),
        "scan_p50_us": float(np.percentile(scan_lat, 50) * 1e6),
        "read_p50_us": float(np.percentile(read_lat, 50) * 1e6),
    }
    return out


def run(n_keys: int = 65_536, n_queries: int = 4_096, repeats: int = 7,
        span_keys: int = 24, n_steady: int = 4_096,
        n_steady_warmup: int = 6_144, batch_size: int = 256,
        out_json: str = DEFAULT_OUT):
    all_keys = make_dataset("lognormal", int(n_keys * 1.25))
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(all_keys))
    keys = np.sort(all_keys[perm[:n_keys]])
    insert_pool = np.ascontiguousarray(all_keys[perm[n_keys:]])
    pv = np.arange(len(keys), dtype=np.int64)

    # the §11 serving-state tier bounds: merges and incremental folds
    # recur every few batches, so the steady-state warmup crosses the
    # full tier lifecycle (delta merge, fold verify, swap) and the
    # measured window can assert zero retraces across in-window folds
    nfl = NFL(NFLConfig(
        flow=FlowConfig(dim=3), flow_train=FlowTrainConfig(epochs=1),
        backend="flat", force_flow=True,
        flat_index=FlatAFLIConfig(rebuild_frac=0.005, delta_cap=256,
                                  fold_step_keys=8192)))
    t0 = time.perf_counter()
    nfl.bulkload(keys, pv)
    t_load = time.perf_counter() - t0
    cap = nfl.cfg.flat_index.scan_cap
    idx = nfl.index

    # ranges spanning ~span_keys consecutive keys in positioning order,
    # so results are dense runs and never near the cap
    zk = _z32(nfl, keys)
    zorder = np.argsort(zk, kind="stable")
    zsorted = zk[zorder]
    starts = rng.integers(0, len(keys) - span_keys - 1, n_queries)
    spans = rng.integers(1, span_keys + 1, n_queries)
    lo_q = keys[zorder[starts]]
    hi_q = keys[zorder[starts + spans]]
    zlo = _z32(nfl, lo_q)
    zhi = _z32(nfl, hi_q)

    dim, theta = nfl.cfg.flow.dim, nfl.cfg.flow.theta
    feats_lo = expand_features(lo_q, nfl.normalizer, dim, theta,
                               dtype=np.float32)
    feats_hi = expand_features(hi_q, nfl.normalizer, dim, theta,
                               dtype=np.float32)

    def fused():
        return nfl.scan_batch(lo_q, hi_q, cap=cap)

    def host_oracle():
        # the ops shim's fallback path, end to end: kernel-NF endpoint
        # transform + the vectorized host merge
        from repro.kernels.nf_forward import nf_forward_pallas
        import jax.numpy as jnp

        a = np.asarray(nf_forward_pallas(jnp.asarray(feats_lo),
                                         nfl._packed_w, nfl._shapes, dim))
        b = np.asarray(nf_forward_pallas(jnp.asarray(feats_hi),
                                         nfl._packed_w, nfl._shapes, dim))
        return idx._range_scan_host(a, b, cap)

    # the naive pre-§12 serving shape: per range, enumerate member keys
    # on the host and resolve them through batched POINT lookups — one
    # serving call per range
    def per_key_loop():
        n_points = 0
        outs = []
        for i in range(n_queries):
            a = int(np.searchsorted(zsorted, zlo[i], side="left"))
            b = int(np.searchsorted(zsorted, zhi[i], side="left"))
            members = keys[zorder[a:b]]
            n_points += len(members)
            outs.append(nfl.lookup_batch(members) if len(members)
                        else np.empty(0, np.int64))
        return outs, n_points

    # correctness cross-checks before timing
    r_fused, c_fused, t_fused_tot = fused()
    r_host, c_host, t_host_tot = host_oracle()
    identical = (np.array_equal(r_fused, r_host)
                 and np.array_equal(c_fused, c_host)
                 and np.array_equal(t_fused_tot, t_host_tot))
    if not identical:
        raise AssertionError("fused range scan diverged from host oracle")
    loop_res, n_points = per_key_loop()
    wrong = 0
    for i in range(n_queries):
        got = np.sort(r_fused[i, :c_fused[i]])
        exp = np.sort(np.asarray(loop_res[i]))
        wrong += int(not np.array_equal(got, exp))
    if wrong:
        raise AssertionError(
            f"fused range scan disagreed with the per-key loop on "
            f"{wrong}/{n_queries} ranges")

    t_fused, cf_w, cf_m = _best_s(fused, repeats)
    t_host, ch_w, ch_m = _best_s(host_oracle, max(repeats // 2, 1))
    t0 = time.perf_counter()  # loop baseline: single timed pass (its
    loop_res, _ = per_key_loop()  # shape buckets are warm from the check)
    t_loop = time.perf_counter() - t0

    steady = _steady_state(nfl, keys, insert_pool, n_ops=n_steady,
                           n_warmup=n_steady_warmup,
                           batch_size=batch_size, cap=cap)

    results = {
        "workload": {"n_keys": int(len(keys)), "n_queries": int(n_queries),
                     "span_keys": int(span_keys), "scan_cap": int(cap),
                     "n_steady": int(n_steady),
                     "n_steady_warmup": int(n_steady_warmup),
                     "batch_size": int(batch_size),
                     "mix": "range_only+steady", "dataset": "lognormal",
                     "flow_dim": dim, "use_flow": bool(nfl.use_flow),
                     "repeats": repeats,
                     "backend": "interpret" if ops.should_interpret()
                     else "tpu",
                     "bulkload_s": t_load,
                     "mean_range_len": float(np.mean(c_fused))},
        "fused": {"wall_s": t_fused, "n_dispatch": 1,
                  "us_per_query": t_fused / n_queries * 1e6,
                  "compiles_warmup": cf_w, "compiles_measure": cf_m},
        "host_oracle": {"wall_s": t_host,
                        "us_per_query": t_host / n_queries * 1e6,
                        "compiles_warmup": ch_w, "compiles_measure": ch_m},
        "per_key_loop": {"wall_s": t_loop,
                         "us_per_query": t_loop / n_queries * 1e6,
                         "n_point_lookups": int(n_points),
                         "n_serving_calls": int(n_queries)},
        "speedup_fused_vs_loop": t_loop / t_fused,
        "speedup_fused_vs_host_oracle": t_host / t_fused,
        "identical_to_host_oracle": identical,
        "wrong": wrong,
        "steady_state": steady,
    }
    if steady["wrong"]:
        raise AssertionError(
            f"steady-state scans diverged from the dict oracle: "
            f"{steady['wrong']}")
    print(f"[range_scan] keys={len(keys)} queries={n_queries} "
          f"fused={t_fused*1e3:.2f}ms host={t_host*1e3:.2f}ms "
          f"loop={t_loop*1e3:.2f}ms "
          f"speedup_vs_loop={t_loop/t_fused:.2f}x "
          f"(vs_host {t_host/t_fused:.2f}x) "
          f"steady retraces={steady['retrace_count']} "
          f"repacks={steady['tier_repacks']} wrong={steady['wrong']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    n = results["workload"]["n_queries"]
    return [
        ("perf_range_scan/per_key_loop",
         results["per_key_loop"]["wall_s"] / n * 1e6,
         f"n_serving_calls={results['per_key_loop']['n_serving_calls']}"),
        ("perf_range_scan/fused",
         results["fused"]["wall_s"] / n * 1e6,
         f"n_dispatch=1;speedup_vs_loop="
         f"{results['speedup_fused_vs_loop']:.2f}"),
    ]
