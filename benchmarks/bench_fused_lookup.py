"""Fused single-dispatch lookup vs the two-dispatch serving path.

The workload is the ISSUE-1 acceptance shape: >= 64k flow-positioned keys,
4k-query read-only batches.  Three timed variants over identical inputs:

* ``two_dispatch``   — the pre-fusion production path: ``nf_transform_keys``
  (NF Pallas kernel + host round trip) followed by the pure-jnp
  ``flat_lookup`` traversal (a second device dispatch);
* ``fused``          — ONE ``pallas_call``: in-kernel NF forward +
  multi-level traversal (``kernels/fused_lookup``);
* ``traversal_only`` — both traversal variants on pre-transformed keys
  (isolates the dispatch/fusion win from the NF cost).

Results (wall clock + dispatch counts + correctness cross-check) go to
``BENCH_fused_lookup.json`` so the perf trajectory is machine-readable.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.feature import expand_features
from repro.core.flat_afli import FlatAFLI, flat_lookup, split_key_bits
from repro.core.flow import FlowConfig
from repro.core.nfl import NFL
from repro.core.train_flow import FlowTrainConfig, train_flow
from repro.data.datasets import make_dataset
from repro.kernels import ops
from repro.kernels.nf_forward import nf_forward_pallas

from benchmarks.common import best_s as _best_s

DEFAULT_OUT = "BENCH_fused_lookup.json"


def run(n_keys: int = 65_536, n_queries: int = 4_096, repeats: int = 9,
        out_json: str = DEFAULT_OUT):
    keys = make_dataset("lognormal", n_keys)
    pv = np.arange(len(keys), dtype=np.int64)

    cfg = FlowConfig(dim=3)
    params, norm, _ = train_flow(keys, cfg, FlowTrainConfig(epochs=1))
    z_build = ops.nf_transform_keys(params, norm, keys, cfg)
    idx = FlatAFLI()
    idx.build(z_build, pv, ikeys=keys)
    packed_w, shapes = NFL._pack_weights_for(params, cfg)

    rng = np.random.default_rng(0)
    q = rng.choice(keys, size=n_queries, replace=False)
    feats = expand_features(q, norm, cfg.dim, cfg.theta, dtype=np.float32)
    hi, lo = split_key_bits(q)
    hi_j, lo_j = jnp.asarray(hi), jnp.asarray(lo)
    kw = dict(max_depth=idx.max_depth,
              dense_iters=idx.cfg.dense_search_iters,
              bucket_cap=idx.cfg.max_bucket,
              dense_window=idx._dense_window_static())

    # both serving variants start from raw query keys: host feature
    # expansion is a shared cost, what differs is everything after it
    def two_dispatch():
        z = ops.nf_transform_keys(params, norm, q, cfg)  # dispatch 1 + host
        res = flat_lookup(idx.arrays, jnp.asarray(z.astype(np.float32)),
                          hi_j, lo_j, **kw)              # dispatch 2
        return np.asarray(res)

    def fused():
        f = expand_features(q, norm, cfg.dim, cfg.theta, dtype=np.float32)
        res, _z, _info = ops.fused_lookup(
            idx.arrays, idx._kernel_pools(), jnp.asarray(f), hi_j, lo_j,
            flow=(packed_w, shapes), **kw)
        return res

    # traversal-only pair: identical pre-transformed inputs
    z32 = jnp.asarray(np.asarray(
        nf_forward_pallas(jnp.asarray(feats), packed_w, shapes, cfg.dim)))

    def traversal_oracle():
        return np.asarray(flat_lookup(idx.arrays, z32, hi_j, lo_j, **kw))

    def traversal_fused():
        res, _z, _info = ops.fused_lookup(
            idx.arrays, idx._kernel_pools(),
            z32.reshape(-1, 1), hi_j, lo_j, flow=None, **kw)
        return res

    # correctness cross-check before timing
    r_two, r_fused = two_dispatch(), fused()
    if not np.array_equal(r_two, r_fused):
        raise AssertionError("fused path diverged from two-dispatch path")
    hit_frac = float((r_fused >= 0).mean())

    t_two, c_two_w, c_two_m = _best_s(two_dispatch, repeats)
    t_fused, c_fused_w, c_fused_m = _best_s(fused, repeats)
    t_trav_o, c_to_w, c_to_m = _best_s(traversal_oracle, repeats)
    t_trav_f, c_tf_w, c_tf_m = _best_s(traversal_fused, repeats)

    results = {
        "workload": {"n_keys": int(len(keys)), "n_queries": int(n_queries),
                     "mix": "read_only", "dataset": "lognormal",
                     "flow_dim": cfg.dim, "repeats": repeats,
                     "backend": "interpret" if ops.should_interpret()
                     else "tpu",
                     "hit_fraction": hit_frac,
                     "pool_bytes": ops.pool_nbytes(idx._kernel_pools()),
                     "max_depth": idx.max_depth},
        "two_dispatch": {"wall_s": t_two, "n_dispatch": 2,
                         "us_per_query": t_two / n_queries * 1e6,
                         "compiles_warmup": c_two_w,
                         "compiles_measure": c_two_m},
        "fused": {"wall_s": t_fused, "n_dispatch": 1,
                  "us_per_query": t_fused / n_queries * 1e6,
                  "compiles_warmup": c_fused_w,
                  "compiles_measure": c_fused_m},
        "traversal_only": {
            "oracle_wall_s": t_trav_o, "fused_wall_s": t_trav_f,
            "compiles_warmup": c_to_w + c_tf_w,
            "compiles_measure": c_to_m + c_tf_m,
            "speedup": t_trav_o / t_trav_f if t_trav_f else float("nan")},
        "speedup_fused_vs_two_dispatch": t_two / t_fused,
        "identical_results": True,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    print(f"[fused_lookup] keys={len(keys)} queries={n_queries} "
          f"two_dispatch={t_two*1e3:.2f}ms fused={t_fused*1e3:.2f}ms "
          f"speedup={t_two/t_fused:.2f}x "
          f"(traversal-only {t_trav_o/t_trav_f:.2f}x)")
    return results


def rows(results) -> List[Tuple]:
    n = results["workload"]["n_queries"]
    return [
        ("perf_fused_lookup/two_dispatch",
         results["two_dispatch"]["wall_s"] / n * 1e6, "n_dispatch=2"),
        ("perf_fused_lookup/fused",
         results["fused"]["wall_s"] / n * 1e6,
         f"n_dispatch=1;speedup="
         f"{results['speedup_fused_vs_two_dispatch']:.2f}"),
    ]
