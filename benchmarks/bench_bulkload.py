"""Paper Fig. 10: bulk-loading time (NF transform + index build)."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.data.datasets import make_dataset

from benchmarks.common import INDEXES, make_bench_index


def run(n_keys: int = 200_000, datasets=("longlat", "lognormal", "ycsb"),
        indexes=None) -> List[Tuple[str, str, float, dict]]:
    indexes = indexes or INDEXES
    rows_out = []
    for ds in datasets:
        keys = make_dataset(ds, n_keys)
        pv = np.arange(len(keys), dtype=np.int64)
        half = len(keys) // 2
        for index in indexes:
            idx = make_bench_index(index)
            t0 = time.perf_counter()
            idx.bulkload(keys[:half], pv[:half])
            dt = time.perf_counter() - t0
            extra = {}
            if hasattr(idx, "metrics"):
                extra = {k: idx.metrics[k] for k in
                         ("flow_train_s", "transform_s", "index_build_s")
                         if k in idx.metrics}
            rows_out.append((ds, index, dt, extra))
            parts = (f" (flow={extra.get('flow_train_s', 0):.2f}s "
                     f"transform={extra.get('transform_s', 0):.2f}s "
                     f"build={extra.get('index_build_s', 0):.2f}s)"
                     if extra else "")
            print(f"[fig10] {ds:11s} {index:6s} bulkload {dt:7.3f}s{parts}")
    return rows_out


def rows(results):
    return [(f"fig10_bulkload/{ds}/{index}", dt * 1e6,
             ";".join(f"{k}={v:.2f}" for k, v in extra.items()))
            for ds, index, dt, extra in results]
