"""Paper Table 1: a classic learned index with vs without the NF transform.

The paper instruments ALEX (tree height, #prediction errors, #predictions,
throughput).  Our ALEX-like baseline is two-level, so we report its
structural telemetry (leaves / expansions / splits) plus the RMI's
prediction-error telemetry, both +/- NF — the same claim surface: the
transform shrinks structure and prediction error on hard distributions.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.flow import FlowConfig, transform_keys
from repro.core.train_flow import FlowTrainConfig, train_flow
from repro.data.datasets import make_dataset
from repro.index import make_index


def run(n_keys: int = 100_000, datasets=("longlat", "facebook")) -> List[dict]:
    cfg = FlowConfig()
    out = []
    for ds in datasets:
        keys = make_dataset(ds, n_keys)
        pv = np.arange(len(keys), dtype=np.int64)
        half = len(keys) // 2
        params, norm, _ = train_flow(keys[:half], cfg, FlowTrainConfig(epochs=2))
        z = transform_keys(params, norm, keys, cfg)
        order = np.argsort(z[:half], kind="stable")

        for label, lkeys, qkeys in (
            ("raw", keys[:half], keys[:half]),
            ("nf", np.sort(z[:half]), np.sort(z[:half])),
        ):
            row = {"dataset": ds, "variant": label}
            alex = make_index("alex")
            alex.bulkload(lkeys, pv[:half])
            t0 = time.perf_counter()
            res = alex.lookup_batch(qkeys[::5])
            row["alex_lookup_mops"] = len(qkeys[::5]) / (time.perf_counter() - t0) / 1e6
            row["alex_leaves"] = alex.stats()["n_leaves"]

            rmi = make_index("rmi")
            rmi.bulkload(lkeys, pv[:half])
            rmi.lookup_batch(qkeys[::5])
            row["rmi_max_err"] = rmi.stats()["max_leaf_err"]
            row["rmi_pred_errors"] = rmi.n_pred_errors
            row["rmi_predictions"] = rmi.n_predictions
            out.append(row)
            print(f"[table1] {ds:9s} {label:3s} "
                  f"alex_mops={row['alex_lookup_mops']:6.3f} "
                  f"rmi_max_err={row['rmi_max_err']:7.0f} "
                  f"rmi_errs={row['rmi_pred_errors']:10d}")
    return out


def rows(results):
    return [(f"table1_alex_nf/{r['dataset']}/{r['variant']}",
             1.0 / max(r["alex_lookup_mops"], 1e-9),
             f"rmi_max_err={r['rmi_max_err']:.0f}") for r in results]
