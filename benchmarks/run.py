"""Benchmark runner — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows after each module's own
human-readable logging.  ``--full`` widens to all 7 datasets and larger op
counts; the default profile finishes on a laptop-class CPU.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7,table2,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", action="append", default=None,
                    help="tag filter, repeatable and/or comma-separated: "
                         "fig7,fig8,fig10,fig11,table1,table2,table3,"
                         "roofline,fused,mixed,serving")
    ap.add_argument("--n-keys", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repeats per variant in the repeat-based "
                         "benches (fused)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes (CI smoke; see "
                         "scripts/verify.sh)")
    args = ap.parse_args()
    only = (set(t for part in args.only for t in part.split(","))
            if args.only else None)

    from benchmarks import (bench_alex_nf, bench_bulkload, bench_conflict,
                            bench_fused_lookup, bench_index_size,
                            bench_latency, bench_mixed_workload,
                            bench_nf_latency, bench_probe_batch,
                            bench_roofline, bench_serving_state,
                            bench_throughput)
    from benchmarks.common import ALL_DATASETS, DEFAULT_DATASETS

    n_keys = args.n_keys or (400_000 if args.full else 100_000)
    if args.smoke and args.n_keys is None:
        n_keys = 8_192
    datasets = ALL_DATASETS if args.full else DEFAULT_DATASETS
    rows = []

    def want(tag):
        return only is None or tag in only

    t0 = time.time()
    if want("fig7"):
        rows += bench_throughput.rows(bench_throughput.run(
            n_keys=n_keys, n_ops=60_000 if args.full else 30_000,
            datasets=datasets))
    if want("fig8"):
        rows += bench_latency.rows(bench_latency.run(n_keys=n_keys))
    if want("fig10"):
        rows += bench_bulkload.rows(bench_bulkload.run(n_keys=2 * n_keys))
    if want("fig11"):
        rows += bench_index_size.rows(bench_index_size.run(n_keys=n_keys))
    if want("table1"):
        rows += bench_alex_nf.rows(bench_alex_nf.run(n_keys=n_keys))
    if want("table2"):
        rows += bench_nf_latency.rows(bench_nf_latency.run())
    if want("probe_batch"):
        rows += bench_probe_batch.rows(bench_probe_batch.run())
    if want("table3"):
        rows += bench_conflict.rows(bench_conflict.run(
            n_keys=n_keys, datasets=datasets if not args.full else None))
    if want("fused"):
        # also emits machine-readable BENCH_fused_lookup.json
        if args.smoke:
            # smoke: no artifact — don't clobber the committed full-size
            # BENCH json with seconds-scale numbers
            rows += bench_fused_lookup.rows(bench_fused_lookup.run(
                n_keys=n_keys, n_queries=1_024,
                repeats=args.repeats or 2, out_json=None))
        else:
            rows += bench_fused_lookup.rows(bench_fused_lookup.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536,
                **({"repeats": args.repeats} if args.repeats else {})))
    if want("mixed"):
        # read/insert mixes; emits BENCH_mixed_workload.json
        if args.smoke:
            rows += bench_mixed_workload.rows(bench_mixed_workload.run(
                n_keys=n_keys, n_ops=1_024, batch_size=256,
                n_warmup=1_024, out_json=None))
        else:
            rows += bench_mixed_workload.rows(bench_mixed_workload.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536))
    if want("serving"):
        # §11 zero-repack serving: steady-state tails + retrace/upload
        # telemetry + legacy before/after; emits BENCH_serving_state.json
        if args.smoke:
            rows += bench_serving_state.rows(bench_serving_state.run(
                n_keys=n_keys, n_ops=1_024, n_warmup=1_024,
                batch_size=256, out_json=None, legacy=False))
        else:
            rows += bench_serving_state.rows(bench_serving_state.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536))
    if want("roofline"):
        rows += bench_roofline.rows(bench_roofline.run())

    print(f"\n# benchmarks completed in {time.time() - t0:.1f}s")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
