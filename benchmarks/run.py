"""Benchmark runner — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows after each module's own
human-readable logging.  ``--full`` widens to all 7 datasets and larger op
counts; the default profile finishes on a laptop-class CPU.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig7,table2,...]

``--compare BENCH_x.json`` re-runs the bench that produced the baseline
JSON at its recorded workload and diffs the two: exit nonzero on any
``wrong > 0`` in the fresh run or a >15% regression on any shared
throughput metric (``throughput_mops`` lower, ``us_per_query`` higher) —
the perf trajectory is machine-checkable against committed baselines.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

REGRESSION_FRAC = 0.15  # tolerated throughput slack vs the baseline


def _walk_numeric(obj, path=""):
    """Yield (path, key, value) for every numeric leaf of a BENCH json."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                yield f"{path}/{k}", k, float(v)
            else:
                yield from _walk_numeric(v, f"{path}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _walk_numeric(v, f"{path}[{i}]")


def _compare_rerun(name: str, base: dict, path: str):
    """Re-run the bench behind a baseline JSON at its recorded workload
    (no artifact emitted — the committed baseline stays untouched)."""
    w = base.get("workload", {})
    n_keys = int(w.get("n_keys", 65_536))
    if name.startswith("BENCH_fused_lookup"):
        from benchmarks import bench_fused_lookup

        return bench_fused_lookup.run(
            n_keys=n_keys, n_queries=int(w.get("n_queries", 4_096)),
            repeats=int(w.get("repeats", 9)), out_json=None)
    if name.startswith("BENCH_range_scan"):
        from benchmarks import bench_range_scan

        return bench_range_scan.run(
            n_keys=n_keys, n_queries=int(w.get("n_queries", 4_096)),
            repeats=int(w.get("repeats", 7)),
            span_keys=int(w.get("span_keys", 24)),
            n_steady=int(w.get("n_steady", 4_096)),
            n_steady_warmup=int(w.get("n_steady_warmup", 6_144)),
            batch_size=int(w.get("batch_size", 256)), out_json=None)
    if name.startswith("BENCH_mixed_workload"):
        from benchmarks import bench_mixed_workload

        # n_warmup is recorded per mix, uniformly — adopt the first's
        mixes = base.get("mixes", {})
        warm = next((m.get("n_warmup") for m in mixes.values()
                     if isinstance(m, dict) and "n_warmup" in m), None)
        return bench_mixed_workload.run(
            n_keys=n_keys, n_ops=int(w.get("n_ops", 12_288)),
            batch_size=int(w.get("batch_size", 256)),
            n_warmup=int(warm) if warm is not None else None,
            out_json=None)
    if name.startswith("BENCH_serving_state"):
        from benchmarks import bench_serving_state

        return bench_serving_state.run(
            n_keys=n_keys, n_ops=int(w.get("n_ops", 8_192)),
            n_warmup=int(w.get("n_warmup", 6_144)),
            batch_size=int(w.get("batch_size", 256)), out_json=None)
    if name.startswith("BENCH_drift"):
        from benchmarks import bench_drift

        return bench_drift.run(
            n_keys=n_keys, n_drift=int(w.get("n_drift", 12_288)),
            n_settle=int(w.get("n_settle", 6_144)),
            n_steady=int(w.get("n_steady", 16_384)),
            batch_size=int(w.get("batch_size", 256)), out_json=None)
    if name.startswith("BENCH_resharding"):
        from benchmarks import bench_resharding

        return bench_resharding.run(
            n_keys=n_keys, n_storm=int(w.get("n_storm", 12_288)),
            n_settle_batches=int(w.get("n_settle_batches", 48)),
            n_steady=int(w.get("n_steady", 16_384)),
            batch_size=int(w.get("batch_size", 256)), out_json=None)
    if name.startswith("BENCH_service"):
        from benchmarks import bench_service

        return bench_service.run(
            n_keys=n_keys, n_reqs=int(w.get("n_reqs", 2_000)),
            n_fault_reqs=int(w.get("n_fault_reqs", 600)),
            batch_size=int(w.get("batch_size", 128)), out_json=None)
    if name.startswith("BENCH_streamed"):
        from benchmarks import bench_streamed

        return bench_streamed.run_at_workload(w, out_json=None)
    if name.startswith("BENCH_sharded"):
        # the sharded bench needs the baseline's forced device topology,
        # and XLA_FLAGS must land before jax initializes — jax is already
        # up in this process, so rerun in a subprocess and read its JSON
        import subprocess
        import tempfile

        with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
            rc = subprocess.call(
                [sys.executable, "-m", "benchmarks.bench_sharded",
                 "--compare-rerun", path, "--out", tmp.name],
                env=dict(os.environ))
            if rc:
                raise AssertionError(
                    f"sharded compare rerun failed (exit {rc})")
            with open(tmp.name) as f:
                return json.load(f)
    raise SystemExit(f"--compare: no runner known for {name}")


def compare(paths) -> int:
    """Diff fresh re-runs against committed baselines; returns the
    number of failures (regressions + nonzero wrong counts)."""
    failures = 0
    for path in paths:
        with open(path) as f:
            base = json.load(f)
        try:
            fresh = _compare_rerun(os.path.basename(path), base, path)
        except AssertionError as e:
            # the benches self-assert correctness (wrong>0, oracle
            # divergence) and raise before returning — count it as a
            # comparison failure and keep going with the next baseline
            print(f"COMPARE FAIL {path}: fresh run failed its own "
                  f"correctness gate: {e}")
            failures += 1
            print(f"# compared {path}: 1 failure(s)")
            continue
        base_vals = {p: (k, v) for p, k, v in _walk_numeric(base)}
        failures_before = failures
        for p, k, v in _walk_numeric(fresh):
            if k == "wrong" and v > 0:
                print(f"COMPARE FAIL {path}{p}: wrong={v:g}")
                failures += 1
                continue
            # baselines predating newly added counter fields simply
            # lack those paths: a missing key reads as 0 (ungated for
            # the ratio metrics below), never a KeyError — old
            # committed BENCH_*.json stay comparable as benches grow
            bv = base_vals.get(p, (k, 0.0))[1]
            if k == "throughput_mops" and v < bv * (1 - REGRESSION_FRAC):
                print(f"COMPARE FAIL {path}{p}: {v:.4g} Mops/s vs "
                      f"baseline {bv:.4g} (>{REGRESSION_FRAC:.0%} slower)")
                failures += 1
            elif k == "us_per_query" and "/fused" in p and bv > 0 \
                    and v > bv / (1 - REGRESSION_FRAC):
                # gate the optimized path's latency only: the reference
                # variants (two_dispatch, per_key_loop, host_oracle) are
                # informational baselines, not the protected trajectory
                print(f"COMPARE FAIL {path}{p}: {v:.4g} us/query vs "
                      f"baseline {bv:.4g} (>{REGRESSION_FRAC:.0%} slower)")
                failures += 1
        here = failures - failures_before
        print(f"# compared {path}: "
              f"{'OK' if not here else f'{here} failure(s)'}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", action="append", default=None,
                    help="tag filter, repeatable and/or comma-separated: "
                         "fig7,fig8,fig10,fig11,table1,table2,table3,"
                         "roofline,fused,mixed,serving,range,sharded,"
                         "drift,resharding,service,streamed")
    ap.add_argument("--n-keys", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repeats per variant in the repeat-based "
                         "benches (fused)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes (CI smoke; see "
                         "scripts/verify.sh)")
    ap.add_argument("--compare", action="append", default=None,
                    metavar="BENCH_JSON",
                    help="re-run the bench behind this committed baseline "
                         "JSON and exit nonzero on >15%% throughput "
                         "regression or any wrong > 0 (repeatable)")
    args = ap.parse_args()
    if args.compare:
        sys.exit(1 if compare(args.compare) else 0)
    only = (set(t for part in args.only for t in part.split(","))
            if args.only else None)

    from benchmarks import (bench_alex_nf, bench_bulkload, bench_conflict,
                            bench_fused_lookup, bench_index_size,
                            bench_latency, bench_mixed_workload,
                            bench_nf_latency, bench_probe_batch,
                            bench_range_scan, bench_roofline,
                            bench_serving_state, bench_throughput)
    from benchmarks.common import ALL_DATASETS, DEFAULT_DATASETS

    n_keys = args.n_keys or (400_000 if args.full else 100_000)
    if args.smoke and args.n_keys is None:
        n_keys = 8_192
    datasets = ALL_DATASETS if args.full else DEFAULT_DATASETS
    rows = []

    def want(tag):
        return only is None or tag in only

    t0 = time.time()
    if want("fig7"):
        rows += bench_throughput.rows(bench_throughput.run(
            n_keys=n_keys, n_ops=60_000 if args.full else 30_000,
            datasets=datasets))
    if want("fig8"):
        rows += bench_latency.rows(bench_latency.run(n_keys=n_keys))
    if want("fig10"):
        rows += bench_bulkload.rows(bench_bulkload.run(n_keys=2 * n_keys))
    if want("fig11"):
        rows += bench_index_size.rows(bench_index_size.run(n_keys=n_keys))
    if want("table1"):
        rows += bench_alex_nf.rows(bench_alex_nf.run(n_keys=n_keys))
    if want("table2"):
        rows += bench_nf_latency.rows(bench_nf_latency.run())
    if want("probe_batch"):
        rows += bench_probe_batch.rows(bench_probe_batch.run())
    if want("table3"):
        rows += bench_conflict.rows(bench_conflict.run(
            n_keys=n_keys, datasets=datasets if not args.full else None))
    if want("fused"):
        # also emits machine-readable BENCH_fused_lookup.json
        if args.smoke:
            # smoke: no artifact — don't clobber the committed full-size
            # BENCH json with seconds-scale numbers
            rows += bench_fused_lookup.rows(bench_fused_lookup.run(
                n_keys=n_keys, n_queries=1_024,
                repeats=args.repeats or 2, out_json=None))
        else:
            rows += bench_fused_lookup.rows(bench_fused_lookup.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536,
                **({"repeats": args.repeats} if args.repeats else {})))
    if want("mixed"):
        # read/insert mixes; emits BENCH_mixed_workload.json
        if args.smoke:
            rows += bench_mixed_workload.rows(bench_mixed_workload.run(
                n_keys=n_keys, n_ops=1_024, batch_size=256,
                n_warmup=1_024, out_json=None))
        else:
            rows += bench_mixed_workload.rows(bench_mixed_workload.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536))
    if want("serving"):
        # §11 zero-repack serving: steady-state tails + retrace/upload
        # telemetry + legacy before/after; emits BENCH_serving_state.json
        if args.smoke:
            rows += bench_serving_state.rows(bench_serving_state.run(
                n_keys=n_keys, n_ops=1_024, n_warmup=1_024,
                batch_size=256, out_json=None, legacy=False))
        else:
            rows += bench_serving_state.rows(bench_serving_state.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536))
    if want("range"):
        # §12 fused tier-merged range scans + tombstone deletes; emits
        # BENCH_range_scan.json (smoke: a .smoke.json artifact so the
        # verify.sh correctness gate still sees the wrong counts without
        # clobbering the committed full-size baseline)
        if args.smoke:
            rows += bench_range_scan.rows(bench_range_scan.run(
                n_keys=n_keys, n_queries=512, repeats=2,
                n_steady=768, n_steady_warmup=512,
                out_json="BENCH_range_scan.smoke.json"))
        else:
            rows += bench_range_scan.rows(bench_range_scan.run(
                n_keys=max(n_keys, 65_536) if args.full else 65_536,
                **({"repeats": args.repeats} if args.repeats else {})))
    if want("drift"):
        # §14 drift-robust serving: re-flow on/off/forced-failure under a
        # drifting insert storm; emits BENCH_drift.json (smoke: a
        # .smoke.json artifact so the verify.sh correctness gate sees the
        # wrong counts without clobbering the committed baseline)
        from benchmarks import bench_drift

        if args.smoke:
            rows += bench_drift.rows(bench_drift.run(
                n_keys=n_keys, n_drift=4_096, n_settle=2_048,
                n_steady=4_096, batch_size=128,
                out_json="BENCH_drift.smoke.json"))
        else:
            rows += bench_drift.rows(bench_drift.run(
                n_keys=max(n_keys, 32_768) if args.full else 32_768))
    if want("resharding"):
        # §18 dynamic resharding: hot-shard split with online boundary
        # migration vs balanced/off/forced-failure; emits
        # BENCH_resharding.json (smoke: a .smoke.json artifact so the
        # verify.sh correctness gate sees the wrong counts without
        # clobbering the committed baseline)
        from benchmarks import bench_resharding

        if args.smoke:
            rows += bench_resharding.rows(bench_resharding.run(
                n_keys=n_keys, n_storm=3_072, n_settle_batches=24,
                n_steady=4_096, batch_size=128,
                out_json="BENCH_resharding.smoke.json"))
        else:
            rows += bench_resharding.rows(bench_resharding.run(
                n_keys=max(n_keys, 32_768) if args.full else 32_768,
                assert_perf=True))
    if want("service"):
        # §16 SLO front-end: goodput-vs-SLO curves, 2x-overload admission
        # contrast, injected-fault degradation; emits BENCH_service.json
        # (smoke: a .smoke.json artifact so the verify.sh correctness
        # gate sees the wrong counts without clobbering the committed
        # baseline)
        from benchmarks import bench_service

        if args.smoke:
            rows += bench_service.rows(bench_service.run(
                n_keys=n_keys, n_reqs=384, n_fault_reqs=192,
                batch_size=64, out_json="BENCH_service.smoke.json",
                fault_modes=("forced_fallback", "transient_errors")))
        else:
            rows += bench_service.rows(bench_service.run(
                n_keys=max(n_keys, 32_768) if args.full else 32_768))
    if want("streamed"):
        # §17 HBM-streaming lookup tier: pool/budget ratio sweep with
        # streamed-vs-oracle margins; emits BENCH_streamed.json
        from benchmarks import bench_streamed

        if args.smoke:
            rows += bench_streamed.rows(bench_streamed.run(
                n_keys=max(n_keys, 16_384), n_reads=1_024, repeats=2,
                ratios=(1, 4), out_json=None))
        else:
            rows += bench_streamed.rows(bench_streamed.run(
                n_keys=max(n_keys, 131_072) if args.full else 131_072))
    if want("sharded"):
        # §13 sharded serving at P=1 vs P=4: needs a forced multi-device
        # host, and XLA_FLAGS must land before jax initializes — jax is
        # already up in this process, so the bench runs as a subprocess
        # (it prints its own rows and emits BENCH_sharded.json)
        import subprocess

        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count=4"
            ).strip()
        cmd = [sys.executable, "-m", "benchmarks.bench_sharded"]
        if args.smoke:
            cmd.append("--smoke")
        if args.n_keys is not None:
            cmd += ["--n-keys", str(args.n_keys)]
        rc = subprocess.call(cmd, env=env)
        if rc:
            raise SystemExit(rc)
    if want("roofline"):
        rows += bench_roofline.rows(bench_roofline.run())

    print(f"\n# benchmarks completed in {time.time() - t0:.1f}s")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
