"""HBM-streaming lookup tier: throughput across pool/budget ratios
(DESIGN.md §17).

One flow-on build, then the same read workload served under a sweep of
VMEM budgets — ``budget = fused_bill / r`` for each ratio ``r``.  At
r=1 the pools fit and the fused rung serves; at every r>1 the fused
rung is outbid and the dispatch ladder must hold the batch on the
kernel path by streaming the rank-ordered scan pool through VMEM in
double-buffered tiles.  Each ratio also times the declared fallback
(``use_streamed_kernel=False`` -> host oracle, the pre-§17 behavior at
that budget) so the JSON records the streamed-vs-oracle margin point by
point; the reference throughput is stored under ``ref_throughput_mops``
on purpose — ``run.py --compare`` gates ``throughput_mops`` (the
protected trajectory) and must not gate the noisy host reference.

Hard gates (the §17 acceptance): wrong == 0 everywhere, and every
ratio through 4x serves with ``path == "streamed"`` — pools several
multiples past the budget never leave the kernel path.  Past the
write-tier crossover (the point where the VMEM-resident write tiers
alone outgrow the budget, so no stream tile can help) the ladder may
demote to the oracle, but only with a structured ``point-streamed``
fallback reason recorded in the entry — a silent demotion fails.

  PYTHONPATH=src python -m benchmarks.bench_streamed

Emits ``BENCH_streamed.json`` (``--smoke``: small sizes, no artifact).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

N_KEYS = 131_072
N_READS = 8_192
REPEATS = 5
RATIOS = (1, 2, 4, 8, 16)


def run(n_keys: int = N_KEYS, n_reads: int = N_READS,
        repeats: int = REPEATS, ratios=RATIOS, delta_cap: int = 256,
        out_json: str | None = "BENCH_streamed.json"):
    import numpy as np

    from benchmarks.common import best_s
    from repro.data.datasets import make_dataset
    from repro.core.flat_afli import FlatAFLIConfig
    from repro.core.flow import FlowConfig
    from repro.core.nfl import NFL, NFLConfig
    from repro.core.train_flow import FlowTrainConfig
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    build_keys = np.sort(make_dataset("lognormal", n_keys))
    payloads = np.arange(n_keys, dtype=np.int64)

    t0 = time.perf_counter()
    nfl = NFL(NFLConfig(backend="flat", force_flow=True,
                        flow=FlowConfig(dim=3),
                        flow_train=FlowTrainConfig(epochs=1),
                        flat_index=FlatAFLIConfig(delta_cap=delta_cap)))
    nfl.bulkload(build_keys, payloads)
    bulkload_s = time.perf_counter() - t0
    idx = nfl.index
    base_cfg = idx.cfg

    q = rng.choice(build_keys, n_reads, replace=True)
    expect = np.searchsorted(build_keys, q).astype(np.int64)

    # one generously-budgeted probe dispatch at the measurement batch
    # shape measures the fused bill — the sweep budgets are expressed
    # as fractions of it (the bill includes the query block, so the
    # probe must use the same batch bucket)
    idx.cfg = dataclasses.replace(base_cfg, vmem_budget=1 << 34)
    nfl.lookup_batch(q)
    assert idx.last_dispatch["path"] == "fused", idx.last_dispatch
    # the full fused residency: pools + query block + tier ride-along
    # (at any budget below this the ladder leaves the fused rung)
    bill = (int(idx.last_dispatch["pool_bytes"])
            + int(idx.last_dispatch["tier_bytes"] or 0))

    result = {
        "workload": {
            "n_keys": n_keys, "n_reads": n_reads, "repeats": repeats,
            "ratios": list(ratios), "delta_cap": delta_cap,
            "dataset": "lognormal", "use_flow": True,
            "fused_bill_bytes": bill,
        },
        "bulkload_s": bulkload_s,
        "ratios": {},
    }

    for r in ratios:
        budget = bill if r == 1 else bill // r
        idx.cfg = dataclasses.replace(base_cfg, vmem_budget=budget)
        ops.reset_fused_lookup_stats()
        res = nfl.lookup_batch(q)
        wrong = int((np.asarray(res) != expect).sum())
        info = dict(idx.last_dispatch)
        # why the streamed rung itself refused, if it did (§15 vocab;
        # info["fallback_reason"] carries the fused rung's reason)
        fb_stream = ops.fused_lookup_stats()["fallback_reasons"].get(
            "point-streamed")
        best, warm_c, meas_c = best_s(lambda: nfl.lookup_batch(q),
                                      repeats)

        # declared fallback at the same budget: the pre-§17 ladder
        # (stream rung unwired) drops the batch to the host oracle
        idx.cfg = dataclasses.replace(base_cfg, vmem_budget=budget,
                                      use_streamed_kernel=False)
        res_ref = nfl.lookup_batch(q)
        ref_wrong = int((np.asarray(res_ref) != expect).sum())
        ref_path = idx.last_dispatch["path"]
        ref_best, _, _ = best_s(lambda: nfl.lookup_batch(q),
                                max(repeats - 2, 1))

        entry = {
            "budget_bytes": int(budget),
            "pool_over_budget_x": bill / budget,
            "path": info.get("path"),
            "throughput_mops": n_reads / best / 1e6,
            "us_per_query": best / n_reads * 1e6,
            "wrong": wrong,
            "wall_s": best,
            "compiles_warmup": warm_c, "compiles_measure": meas_c,
            "stream_tile": info.get("stream_tile"),
            "tiles_streamed": info.get("tiles_streamed"),
            "pool_bytes": info.get("pool_bytes"),
            "pool_stream_bytes": info.get("pool_stream_bytes"),
            "tier_path": info.get("tier_path"),
            "ref_path": ref_path,
            "ref_throughput_mops": n_reads / ref_best / 1e6,
            "ref_wrong": ref_wrong,
            "speedup_vs_ref": ref_best / best,
        }
        result["ratios"][f"x{r}"] = entry
        print(f"x{r}: {entry['path']} {entry['throughput_mops']:.3f} "
              f"Mops/s (tile={entry['stream_tile']}, "
              f"bill {entry['pool_bytes'] / 2 ** 20 if entry['pool_bytes'] else 0:.1f} MiB "
              f"vs budget {budget / 2 ** 20:.1f} MiB) | ref "
              f"{ref_path} {entry['ref_throughput_mops']:.3f} Mops/s | "
              f"{entry['speedup_vs_ref']:.2f}x | wrong={wrong}")

        # §17 acceptance gates
        assert wrong == 0 and ref_wrong == 0, \
            f"x{r}: wrong answers (streamed={wrong}, ref={ref_wrong})"
        if r == 1:
            assert entry["path"] == "fused", entry["path"]
        elif entry["path"] == "streamed":
            assert entry["pool_bytes"] <= budget, \
                f"x{r}: streamed bill exceeds the budget"
        else:
            # past the write-tier crossover: demotion is allowed only
            # above the 4x acceptance floor, and never silently
            assert r > 4, \
                f"x{r}: left the kernel path below the 4x floor " \
                f"({entry['path']})"
            assert fb_stream \
                and fb_stream.get("route") == "point-streamed" \
                and fb_stream.get("over_bytes", 0) > 0, \
                f"x{r}: demoted without a structured reason " \
                f"({fb_stream})"
            entry["fallback_reason"] = fb_stream

    streamed = {k: v for k, v in result["ratios"].items()
                if v["path"] == "streamed"}
    if streamed:
        worst = min(streamed.values(), key=lambda v: v["speedup_vs_ref"])
        result["crossover"] = {
            "max_ratio_on_kernel_path": max(
                v["pool_over_budget_x"] for v in streamed.values()),
            "min_speedup_vs_oracle": worst["speedup_vs_ref"],
            "all_streamed_beat_oracle": all(
                v["speedup_vs_ref"] > 1.0 for v in streamed.values()),
        }
        print(f"kernel path held to "
              f"{result['crossover']['max_ratio_on_kernel_path']:.1f}x "
              f"pool/budget; min streamed-vs-oracle speedup "
              f"{result['crossover']['min_speedup_vs_oracle']:.2f}x")

    idx.cfg = base_cfg
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out_json}")
    return result


def rows(result):
    out = []
    for name, e in result["ratios"].items():
        out.append((f"streamed_{name}", e["us_per_query"],
                    f"{e['throughput_mops']:.3f}Mops_{e['path']}_"
                    f"tile={e['stream_tile']}"))
    if "crossover" in result:
        out.append(("streamed_crossover", 0.0,
                    f"{result['crossover']['max_ratio_on_kernel_path']:.0f}"
                    f"x_pool_over_budget"))
    return out


def run_at_workload(w: dict, out_json: str | None = None):
    """Re-run at a recorded baseline's workload block (``--compare``)."""
    return run(
        n_keys=int(w.get("n_keys", N_KEYS)),
        n_reads=int(w.get("n_reads", N_READS)),
        repeats=int(w.get("repeats", REPEATS)),
        ratios=tuple(w.get("ratios", RATIOS)),
        delta_cap=int(w.get("delta_cap", 256)),
        out_json=out_json)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes, no JSON artifact")
    ap.add_argument("--n-keys", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        run(n_keys=args.n_keys or 16_384, n_reads=1_024, repeats=2,
            ratios=(1, 4), out_json=args.out)
    else:
        run(**{**({"n_keys": args.n_keys} if args.n_keys else {}),
               **({"out_json": args.out} if args.out else {})})


if __name__ == "__main__":
    main()
