"""SLO-aware serving front-end: goodput-vs-SLO curves, overload
admission contrast, and graceful degradation under injected faults
(DESIGN.md §16).

Everything before this bench measured the index on perfectly
pre-batched closed-loop traffic; this one drives the §16 ``FrontEnd``
with *open-loop* request traces (arrivals never slow down for a backed
up server) and measures what a caller with a deadline actually gets:

* **slo_curves** — Poisson and bursty (on/off, 4x peak) point-lookup
  traces at a sub-saturation load, replayed per SLO from tight to
  slack, on the flat AND sharded backends.  Goodput (fraction of
  admitted requests completed on time) must grow as the SLO loosens.
* **overload** — the same Poisson trace offered at ~2x the calibrated
  capacity, with admission control on vs off.  With admission on, the
  front end sheds early and the served-latency p999 stays bounded near
  the SLO; with it off nothing is shed and the tail grows with queue
  depth.  The headline gate is the *ratio*: admission must cut p999.
* **faults** — mixed read/write traffic under each injected fault
  (forced kernel→oracle fallback, periodic device stalls + slow folds,
  transient dispatch errors, retrain failure under drift): the ladder
  must degrade — fewer requests per second, higher tail — but never
  break: exact terminal accounting and zero oracle divergence in every
  mode.

Every mode cross-checks served results against a dict oracle driven
from the ``on_batch_dispatched`` hook (dispatch order == index
serialization order, so expectations are snapshotted exactly when the
index observes the batch).  Any ``wrong`` fails the run.  Emits
machine-readable ``BENCH_service.json``.
"""

from __future__ import annotations

import bisect
import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.drift import DriftConfig
from repro.core.flat_afli import FlatAFLIConfig
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.serve import faults
from repro.serve.frontend import FrontEnd, FrontEndConfig, ServiceRequest

DEFAULT_OUT = "BENCH_service.json"
BACKENDS = ("flat", "sharded")
TRACES = ("poisson", "bursty")
FAULT_MODES = ("forced_fallback", "device_stall_slow_fold",
               "transient_errors", "retrain_failure")


# --------------------------------------------------------------- oracle
class _Oracle:
    """Dict oracle applied in dispatch order via the front-end hook.

    Range expectations use a sorted-key bisect (the dict alone would be
    O(n) per range).  ``totals`` is not compared: it counts span
    *candidates* pre-dedup (including shadowed copies), a capacity
    telemetry value, not a result."""

    def __init__(self, oracle: dict):
        self.d = dict(oracle)
        self.sorted_keys = sorted(self.d)
        self.expected = {}

    def _resort(self):
        self.sorted_keys = sorted(self.d)

    def hook(self, op, reqs):
        if op == "point":
            for r in reqs:
                self.expected[r.rid] = self.d.get(r.key, -1)
        elif op == "range":
            ks = self.sorted_keys
            for r in reqs:
                i = bisect.bisect_left(ks, r.key)
                j = bisect.bisect_left(ks, r.hi)
                self.expected[r.rid] = [self.d[k] for k in ks[i:j]]
        elif op == "insert":
            for r in reqs:
                self.d[r.key] = r.payload
            self._resort()
        else:  # delete
            for r in reqs:
                self.expected[r.rid] = r.key in self.d
                self.d.pop(r.key, None)
            self._resort()

    def check(self, reqs) -> int:
        wrong = 0
        for r in reqs:
            if r.rid not in self.expected or r.result is None:
                continue
            exp = self.expected[r.rid]
            if r.op in ("point", "delete"):
                wrong += int(r.result != exp)
            elif r.op == "range":
                got, _tot = r.result
                wrong += int(list(got) != list(exp))
        return wrong


# ------------------------------------------------------------ workloads
def _build(backend: str, n_keys: int, seed: int):
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.uniform(0.0, 1e6, 3 * n_keys))[:n_keys]
    pv = np.arange(keys.shape[0], dtype=np.int64)
    nfl = NFL(NFLConfig(backend="flat", force_flow=False,
                        shards=2 if backend == "sharded" else 1))
    nfl.bulkload(keys, pv)
    return nfl, keys, dict(zip(keys.tolist(), pv.tolist()))


def _calibrate_rps(nfl, keys, batch: int, rng) -> float:
    """Measured steady per-request service rate at the configured fill
    size — the load axis is expressed relative to this, so the bench
    tracks the algorithm, not the host."""
    q = rng.choice(keys, batch, replace=False)
    for _ in range(3):
        nfl.lookup_batch(q)          # warm the shape bucket
    t0 = time.perf_counter()
    n_rep = 5
    for _ in range(n_rep):
        nfl.lookup_batch(rng.choice(keys, batch, replace=False))
    dt = time.perf_counter() - t0
    return n_rep * batch / dt


def _frontend_capacity(nfl, keys, batch: int, rng) -> float:
    """True serving rate *through the front end* (batching overhead and
    double-buffered overlap included): submit a standing burst, drain,
    divide.  The overload axis is expressed against this — the sync
    ``_calibrate_rps`` underestimates the async pipeline, and \"2x\"
    must mean twice what the loop can actually sustain."""
    n = 8 * batch
    best = 0.0
    # three probes, best-of: the first pays the jit/warmup cost of every
    # partial-batch shape bucket the loop happens to form — that is
    # compile time, not service time
    for _ in range(3):
        fe = FrontEnd(nfl, FrontEndConfig(max_batch=batch,
                                          batch_timeout_s=1e-4))
        reqs = _point_reqs(n, keys, 600.0, rng)
        t0 = time.perf_counter()
        for r in reqs:
            fe.submit(r)
        fe.drain()
        best = max(best, n / (time.perf_counter() - t0))
    return best


def _arrivals(kind: str, n: int, rate_rps: float, rng) -> np.ndarray:
    """Open-loop arrival times (seconds, relative).  ``bursty`` is an
    on/off process: same mean rate, but arrivals bunch into bursts at
    4x the mean with idle gaps between — the worst case for a
    fill-or-timeout batcher's head-of-line latency."""
    if kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate_rps, n))
    gaps = rng.exponential(1.0 / (4.0 * rate_rps), n)
    burst = 32
    for i in range(0, n, burst):
        gaps[i] += rng.exponential(3.0 * burst / (4.0 * rate_rps))
    return np.cumsum(gaps)


def _point_reqs(n: int, keys, deadline_s: float, rng):
    ks = rng.choice(keys, n)
    return [ServiceRequest(i, "point", float(ks[i]), deadline_s=deadline_s)
            for i in range(n)]


def _mixed_reqs(n: int, keys, spare, deadline_s: float, rng,
                p=(0.6, 0.1, 0.2, 0.1)):
    reqs, si, pool = [], 0, list(keys)
    for rid in range(n):
        u = rng.random()
        if u < p[0] or si >= len(spare):
            reqs.append(ServiceRequest(rid, "point", float(rng.choice(pool)),
                                       deadline_s=deadline_s))
        elif u < p[0] + p[1]:
            lo = float(rng.choice(pool))
            reqs.append(ServiceRequest(rid, "range", lo, hi=lo * (1 + 1e-3),
                                       deadline_s=deadline_s))
        elif u < p[0] + p[1] + p[2]:
            reqs.append(ServiceRequest(rid, "insert", float(spare[si]),
                                       payload=1_000_000 + si,
                                       deadline_s=deadline_s))
            pool.append(float(spare[si]))
            si += 1
        else:
            reqs.append(ServiceRequest(rid, "delete",
                                       float(rng.choice(pool)),
                                       deadline_s=deadline_s))
    return reqs


# ----------------------------------------------------------- one replay
def _replay(nfl, oracle: dict, reqs, arrivals, fe_cfg: FrontEndConfig):
    orc = _Oracle(oracle)
    fe = FrontEnd(nfl, fe_cfg)
    fe.on_batch_dispatched = orc.hook
    dur = fe.run_trace(reqs, arrivals)
    s = fe.stats()
    n = len(reqs)
    return {
        "n_requests": n,
        "duration_s": dur,
        "offered_rps": n / float(arrivals[-1]) if len(arrivals) else 0.0,
        "goodput_rps": (s["completed"] - s["completed_late"]) / dur,
        "goodput_frac": (s["completed"] - s["completed_late"]) / n,
        "completed": s["completed"], "shed": s["shed"],
        "expired": s["expired"], "completed_late": s["completed_late"],
        "batches": s["batches"], "retries": s["retries"],
        "retry_giveups": s["retry_giveups"],
        "reasons": s["reasons"],
        "latency_served": s["latency_served"],
        "latency_ontime": s["latency_ontime"],
        "wrong": orc.check(reqs),
        "accounting_exact": (s["completed"] + s["shed"] + s["expired"]
                             == s["admitted"]),
    }


def _check(mode: str, r: dict) -> None:
    if r["wrong"]:
        raise AssertionError(f"{mode}: {r['wrong']} served results "
                             f"diverged from the dict oracle")
    if not r["accounting_exact"]:
        raise AssertionError(f"{mode}: terminal accounting not exact")


# ----------------------------------------------------------------- run
def run(n_keys: int = 32_768, n_reqs: int = 2_000, n_fault_reqs: int = 600,
        batch_size: int = 128, out_json: str = DEFAULT_OUT,
        assert_headline: bool = True, fault_modes=FAULT_MODES):
    rng = np.random.default_rng(11)
    results = {"workload": {
        "n_keys": n_keys, "n_reqs": n_reqs, "n_fault_reqs": n_fault_reqs,
        "batch_size": batch_size, "dataset": "uniform",
        "traces": list(TRACES), "backends": list(BACKENDS),
    }}

    # ---- goodput-vs-SLO curves, per backend x trace shape -------------
    for backend in BACKENDS:
        nfl, keys, oracle = _build(backend, n_keys, seed=3)
        cap = _calibrate_rps(nfl, keys, batch_size, rng)
        base_batch_s = batch_size / cap
        # SLOs from "about one batch time" (tight) to "many batch times"
        # (slack), expressed off the calibrated service time so the curve
        # shape is host-independent
        slos = [2.0 * base_batch_s, 8.0 * base_batch_s, 40.0 * base_batch_s]
        bres = {"capacity_rps": cap, "base_batch_s": base_batch_s}
        for trace in TRACES:
            pts = []
            for slo in slos:
                arr = _arrivals(trace, n_reqs, 0.7 * cap, rng)
                reqs = _point_reqs(n_reqs, keys, slo, rng)
                r = _replay(nfl, oracle, reqs, arr,
                            FrontEndConfig(max_batch=batch_size,
                                           batch_timeout_s=base_batch_s / 4))
                r["slo_s"] = slo
                _check(f"{backend}/{trace}/slo={slo:.2g}", r)
                pts.append(r)
                print(f"[service {backend}/{trace}] slo={slo * 1e3:.2f}ms "
                      f"goodput={r['goodput_frac']:.3f} "
                      f"shed={r['shed']} expired={r['expired']} "
                      f"late={r['completed_late']} wrong={r['wrong']}")
            bres[trace] = {"slo_curve": pts}
        results[backend] = bres

    # ---- 2x overload: admission on vs off ----------------------------
    nfl, keys, oracle = _build("flat", n_keys, seed=5)
    cap = _frontend_capacity(nfl, keys, batch_size, rng)
    slo = 8.0 * batch_size / cap
    # sustained overload needs the trace to span many SLOs at 2x the
    # sustainable rate — a burst shorter than one SLO just fits the
    # deadline and sheds nothing; 96 batches of arrivals = 12 SLO spans
    n_over = 96 * batch_size
    over = {"capacity_rps": cap, "slo_s": slo, "n_requests": n_over}
    for admission in (True, False):
        arr = _arrivals("poisson", n_over, 2.0 * cap, rng)
        reqs = _point_reqs(n_over, keys, slo, rng)
        r = _replay(nfl, oracle, reqs, arr,
                    FrontEndConfig(max_batch=batch_size,
                                   batch_timeout_s=batch_size / cap / 4,
                                   admission=admission,
                                   expire_queued=admission))
        r["slo_s"] = slo
        mode = "admission_on" if admission else "admission_off"
        _check(f"overload/{mode}", r)
        over[mode] = r
        print(f"[service overload/{mode}] "
              f"p999_served={r['latency_served']['p999_ns'] / 1e6:.2f}ms "
              f"goodput={r['goodput_frac']:.3f} shed={r['shed']} "
              f"wrong={r['wrong']}")
    results["overload"] = over

    # ---- injected faults: degrade, never break -----------------------
    fres = {}
    for mode in fault_modes:
        if mode == "retrain_failure":
            # this mode needs enough insert volume to drive the drift
            # monitor through a check window and trigger a (failing)
            # retrain — floor the regime independently of the smoke
            # request count
            nr = max(n_fault_reqs, 420)
            frng = np.random.default_rng(31)
            keys = np.unique(frng.lognormal(0, 2.0, 4000))[:1200]
            pv = np.arange(keys.shape[0], dtype=np.int64)
            nfl = NFL(NFLConfig(
                backend="flat", force_flow=True,
                flow_train=FlowTrainConfig(epochs=1),
                drift=DriftConfig(reflow=True, threshold=1.2, min_tail=2,
                                  check_every=64, window_keys=1024,
                                  cooldown_keys=512, train_epochs=1,
                                  train_batch=128, steps_per_tick=8,
                                  seed=0)))
            nfl.bulkload(keys, pv)
            oracle = dict(zip(keys.tolist(), pv.tolist()))
            centers = np.quantile(keys, np.linspace(0.9, 0.999, 8))
            spare = np.unique(np.concatenate(
                [c * (1 + frng.uniform(0, 1e-4, nr)) for c in centers]))
            spare = spare[~np.isin(spare, keys)]
            plan = faults.FaultPlan(retrain_failure=True)
            # no ranges: flow-on range semantics follow the
            # NF-transformed positioning order (see NFL.scan_batch),
            # which a key-order dict oracle cannot model
            mix = (0.45, 0.0, 0.5, 0.05)
        else:
            frng = np.random.default_rng(23)
            nfl, keys, oracle = _build("flat", max(n_keys // 4, 2_048),
                                       seed=7)
            spare = np.unique(frng.uniform(2e6, 3e6, n_fault_reqs))
            mix = (0.6, 0.1, 0.2, 0.1)
            plan = {
                "forced_fallback": faults.FaultPlan(force_oracle=True),
                "device_stall_slow_fold": faults.FaultPlan(
                    device_stall_s=5e-4, stall_every=4, fold_stall_s=5e-4),
                "transient_errors": faults.FaultPlan(
                    dispatch_error_every=5),
            }[mode]
        nr = nr if mode == "retrain_failure" else n_fault_reqs
        cap = _calibrate_rps(nfl, keys, batch_size, rng)
        reqs = _mixed_reqs(nr, keys, spare, 60.0, frng, p=mix)
        arr = _arrivals("poisson", nr, 0.7 * cap, rng)
        faults.injection_stats(reset=True)
        with faults.inject(plan, nfl=nfl):
            r = _replay(nfl, oracle, reqs, arr,
                        FrontEndConfig(max_batch=batch_size,
                                       batch_timeout_s=1e-3,
                                       admission=False,
                                       expire_queued=False))
        r["fault_stats"] = faults.injection_stats()
        if mode == "retrain_failure":
            d = nfl.dispatch_stats()["drift"]
            r["drift_stats"] = {k: d[k] for k in (
                "retrain_attempts", "retrain_failures",
                "reflows_completed", "use_flow")}
        _check(f"faults/{mode}", r)
        fres[mode] = r
        print(f"[service fault/{mode}] completed={r['completed']} "
              f"retries={r['retries']} "
              f"p999_served={r['latency_served']['p999_ns'] / 1e6:.2f}ms "
              f"wrong={r['wrong']}")
    results["faults"] = fres

    # ---- headline gates ----------------------------------------------
    results["wrong_total"] = 0  # _check raised otherwise
    results["accounting_exact_everywhere"] = True
    results["goodput_grows_with_slo"] = all(
        results[b][t]["slo_curve"][-1]["goodput_frac"]
        >= results[b][t]["slo_curve"][0]["goodput_frac"]
        for b in BACKENDS for t in TRACES)
    on, off = over["admission_on"], over["admission_off"]
    results["admission_sheds_under_overload"] = on["shed"] > 0
    results["admission_bounds_p999"] = (
        on["latency_served"]["p999_ns"]
        <= off["latency_served"]["p999_ns"])
    if "forced_fallback" in fres:
        results["forced_fallback_served_by_oracle"] = (
            fres["forced_fallback"]["fault_stats"]["forced_fallbacks"] > 0)
    if "transient_errors" in fres:
        results["transient_errors_retried"] = (
            fres["transient_errors"]["retries"] > 0
            and fres["transient_errors"]["retry_giveups"] == 0)
    if "retrain_failure" in fres:
        results["retrain_failure_never_swaps"] = (
            fres["retrain_failure"]["drift_stats"]["retrain_failures"] >= 1
            and fres["retrain_failure"]["drift_stats"][
                "reflows_completed"] == 0)
    if assert_headline:
        assert results["goodput_grows_with_slo"], \
            "goodput did not grow from tightest to loosest SLO"
        assert results["admission_sheds_under_overload"], \
            "admission control shed nothing at 2x offered load"
        assert results["admission_bounds_p999"], \
            "admission-on p999 exceeded admission-off under overload"
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    out = []
    for b in BACKENDS:
        br = results.get(b)
        if not br:
            continue
        for t in TRACES:
            pts = br.get(t, {}).get("slo_curve", [])
            if not pts:
                continue
            tight, slack = pts[0], pts[-1]
            out.append((
                f"service/{b}/{t}",
                slack["latency_ontime"]["p50_ns"] / 1e3,
                f"goodput={tight['goodput_frac']:.2f}->"
                f"{slack['goodput_frac']:.2f};"
                f"slo_ms={tight['slo_s'] * 1e3:.2f}->"
                f"{slack['slo_s'] * 1e3:.2f}"))
    over = results.get("overload", {})
    if over:
        on = over["admission_on"]["latency_served"]["p999_ns"] / 1e6
        off = over["admission_off"]["latency_served"]["p999_ns"] / 1e6
        out.append(("service/overload_2x", on * 1e3,
                    f"p999_ms_on={on:.2f};p999_ms_off={off:.2f};"
                    f"bounded={results.get('admission_bounds_p999')}"))
    for mode, r in results.get("faults", {}).items():
        out.append((
            f"service/fault_{mode}",
            r["latency_served"]["p50_ns"] / 1e3,
            f"completed={r['completed']};retries={r['retries']};"
            f"wrong={r['wrong']}"))
    return out


if __name__ == "__main__":
    run()
