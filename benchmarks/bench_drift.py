"""Drift-robust serving: p999 under distribution drift, re-flow on vs
off vs forced-retrain-failure (DESIGN.md §14).

The flow is fitted once at bulkload; this bench drives the exact
pathology §14 exists for — sustained insert traffic from tight
micro-clusters the stale transform collapses into a handful of model
slots — and measures the steady-state read tail afterwards in three
modes over the identical keyed workload:

* **reflow_on** — the drift monitor triggers a background retrain, the
  candidate passes the ``accept_candidate`` margin gate, and the
  structure is atomically re-keyed at a fold boundary.  The released
  probe-window ratchets are the mechanism the tail recovery rides.
* **reflow_off** — telemetry only: the drift score is visible in
  ``dispatch_stats()["drift"]`` but serving keeps the stale transform
  and its ratcheted probe windows.
* **retrain_fail** — every retrain attempt raises (injected fault); the
  degradation ladder must keep serving the stale transform with zero
  wrong answers and bounded insert stalls.

Every lookup batch in every phase is cross-checked against a dict
oracle (last-write-wins); any ``wrong`` fails the run.  Headline:
``reflow_improves_tail`` — the re-flow-on steady-state read p999 (and
p50) strictly beats re-flow-off after drift.  Emits machine-readable
``BENCH_drift.json``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.drift import DriftConfig
from repro.core.flat_afli import FlatAFLIConfig
from repro.core.flow import FlowConfig
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.data.datasets import make_dataset

DEFAULT_OUT = "BENCH_drift.json"
MODES = ("reflow_on", "reflow_off", "retrain_fail")


def _pct(lat_ns: np.ndarray):
    if not len(lat_ns):
        return {}
    return {
        "p50_ns": float(np.percentile(lat_ns, 50)),
        "p99_ns": float(np.percentile(lat_ns, 99)),
        "p999_ns": float(np.percentile(lat_ns, 99.9)),
        "max_ns": float(lat_ns.max()),
    }


def _drift_keys(base: np.ndarray, n_drift: int, seed: int) -> np.ndarray:
    """Micro-cluster drift traffic: 16 tight clusters at high in-range
    quantiles.  Spreading the drift over many clusters is what moves the
    gamma-percentile tail — a single mega-conflict slot would not
    (``tail_conflict_degree`` is a percentile over occupied slots)."""
    rng = np.random.default_rng(seed)
    centers = np.quantile(base, np.linspace(0.80, 0.999, 16))
    drift = np.unique(np.concatenate(
        [c * (1 + rng.uniform(0, 1e-4, n_drift // 16)) for c in centers]))
    drift = drift[~np.isin(drift, base)]
    rng.shuffle(drift)
    return drift


def _mixed_phase(nfl, oracle, ins_batches, rng, read_batch: int):
    """Insert the drifting batches, interleaving oracle-checked reads.
    Returns the phase result (read/insert latencies, wrong count)."""
    read_lat, ins_call_s = [], []
    wrong = 0
    n_ops = 0
    t0_run = time.perf_counter()
    for k, v in ins_batches:
        t0 = time.perf_counter()
        nfl.insert_batch(k, v)
        ins_call_s.append(time.perf_counter() - t0)
        for kk, vv in zip(k.tolist(), v.tolist()):
            oracle[kk] = vv
        live = np.array(sorted(oracle))
        q = rng.choice(live, min(read_batch, live.shape[0]), replace=False)
        t0 = time.perf_counter()
        res = nfl.lookup_batch(q)
        read_lat.append((time.perf_counter() - t0) / q.shape[0])
        exp = np.array([oracle[kk] for kk in q.tolist()])
        wrong += int((res != exp).sum())
        n_ops += k.shape[0] + q.shape[0]
    t_run = time.perf_counter() - t0_run
    ins_s = np.asarray(ins_call_s)
    return {
        "n_ops": n_ops,
        "run_s": t_run,
        "read": _pct(np.asarray(read_lat) * 1e9),
        "max_insert_call_s": float(ins_s.max()) if len(ins_s) else 0.0,
        "p50_insert_call_s": float(np.median(ins_s)) if len(ins_s) else 0.0,
        "wrong": wrong,
    }


def _steady_phase(nfl, oracle, rng, n_batches: int, batch: int):
    """Read-only steady window after the drift storm has settled.  A few
    unmeasured batches first: in re-flow-on mode the swap just happened,
    and the first post-swap reads pay one-time upload/trace cost that is
    not steady state.  Each query batch is timed best-of-3 so the
    percentiles capture the *systematic* per-batch probe cost the drift
    degrades (host scheduler / allocator spikes would otherwise own the
    p999 and drown the structural signal)."""
    live = np.array(sorted(oracle))
    bs = min(batch, live.shape[0])
    for _ in range(4):
        nfl.lookup_batch(rng.choice(live, bs, replace=False))
    lat = []
    wrong = 0
    t0_run = time.perf_counter()
    for _ in range(n_batches):
        q = rng.choice(live, bs, replace=False)
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            res = nfl.lookup_batch(q)
            best = min(best, time.perf_counter() - t0)
        lat.append(best / q.shape[0])
        exp = np.array([oracle[kk] for kk in q.tolist()])
        wrong += int((res != exp).sum())
    t_run = time.perf_counter() - t0_run
    n = n_batches * 3 * bs
    return {
        "n_reads": n,
        "run_s": t_run,
        "throughput_mops": n / t_run / 1e6,
        "read": _pct(np.asarray(lat) * 1e9),
        "wrong": wrong,
    }


def _run_mode(mode: str, keys, drift, *, n_settle: int, n_steady: int,
              batch_size: int, seed: int):
    pv = np.arange(len(keys), dtype=np.int64)
    nfl = NFL(NFLConfig(
        backend="flat", force_flow=True, flow=FlowConfig(),
        flow_train=FlowTrainConfig(epochs=1),
        flat_index=FlatAFLIConfig(fold_step_keys=8192),
        drift=DriftConfig(reflow=(mode != "reflow_off"), threshold=1.5,
                          min_tail=4, check_every=1024, window_keys=4096,
                          cooldown_keys=4096, train_epochs=2,
                          train_batch=256, steps_per_tick=4, seed=seed)))
    t0 = time.perf_counter()
    nfl.bulkload(keys, pv)
    t_load = time.perf_counter() - t0
    if mode == "retrain_fail":
        def _boom(sample, attempt):
            raise RuntimeError("injected retrain fault")

        nfl._reflow.train_factory = _boom

    rng = np.random.default_rng(seed + 1)
    oracle = dict(zip(keys.tolist(), pv.tolist()))
    # warmup: prime the read-path shape buckets, then zero the counters
    # so every later phase reads per-phase counts
    nfl.lookup_batch(rng.choice(keys, batch_size, replace=False))
    nfl.lookup_batch(rng.choice(keys, batch_size // 2, replace=False))
    nfl.dispatch_stats(reset=True)

    # ---- drift storm: micro-cluster inserts interleaved with reads
    ins_batches = [
        (drift[i:i + batch_size],
         np.arange(drift[i:i + batch_size].shape[0], dtype=np.int64)
         + 1_000_000_000 + i)
        for i in range(0, drift.shape[0], batch_size)]
    drift_res = _mixed_phase(nfl, oracle, ins_batches, rng,
                             read_batch=batch_size)

    # ---- settle: identical trickle traffic in every mode; with re-flow
    # on this is where the retrain finishes and the re-key fold swaps in
    lo = float(drift.min())
    settle_keys = np.unique(lo * (1 + rng.uniform(0, 1e-7, n_settle)))
    settle_batches = [
        (settle_keys[i:i + 32],
         np.arange(settle_keys[i:i + 32].shape[0], dtype=np.int64)
         + 2_000_000_000 + i)
        for i in range(0, settle_keys.shape[0], 32)]
    settle_res = _mixed_phase(nfl, oracle, settle_batches, rng,
                              read_batch=64)

    steady = _steady_phase(nfl, oracle, rng,
                           n_batches=max(n_steady // batch_size, 1),
                           batch=batch_size)
    d = nfl.dispatch_stats()["drift"]
    sig = d.pop("signals")
    return {
        "bulkload_s": t_load,
        "drift_phase": drift_res,
        "settle_phase": settle_res,
        "steady": steady,
        "drift_stats": {k: d[k] for k in (
            "state", "last_score", "last_serving_tail", "baseline_tail",
            "checks", "triggers", "retrain_attempts", "retrain_failures",
            "candidates_rejected", "reflows_started", "reflows_completed",
            "identity_switches", "use_flow")},
        "signals": {k: sig[k] for k in (
            "max_depth", "static_max_depth", "static_dense_window",
            "run_window", "delta_window", "n_reflows", "n_rebuilds")},
    }


def run(n_keys: int = 32_768, n_drift: int = 12_288, n_settle: int = 6_144,
        n_steady: int = 16_384, batch_size: int = 256,
        out_json: str = DEFAULT_OUT, assert_headline: bool = True):
    base = np.unique(make_dataset("lognormal", n_keys))
    drift = _drift_keys(base, n_drift, seed=0)
    results = {"workload": {
        "n_keys": int(base.shape[0]), "n_drift": int(drift.shape[0]),
        "n_settle": n_settle, "n_steady": n_steady,
        "batch_size": batch_size, "dataset": "lognormal",
        "drift_shape": "16 micro-clusters at q0.80..q0.999",
    }}
    for mode in MODES:
        results[mode] = _run_mode(mode, base, drift, n_settle=n_settle,
                                  n_steady=n_steady,
                                  batch_size=batch_size, seed=7)
        r = results[mode]
        st = r["drift_stats"]
        print(f"[drift {mode}] steady p50="
              f"{r['steady']['read'].get('p50_ns', 0) / 1e3:.1f}us p999="
              f"{r['steady']['read'].get('p999_ns', 0) / 1e3:.1f}us "
              f"score={st['last_score']:.2f} "
              f"reflows={st['reflows_completed']} "
              f"failures={st['retrain_failures']} "
              f"windows={r['signals']['run_window']}/"
              f"{r['signals']['static_dense_window']} "
              f"wrong={r['drift_phase']['wrong']}"
              f"+{r['settle_phase']['wrong']}+{r['steady']['wrong']}")
        wrong = (r["drift_phase"]["wrong"] + r["settle_phase"]["wrong"]
                 + r["steady"]["wrong"])
        if wrong:
            raise AssertionError(
                f"drift {mode}: {wrong} lookups diverged from the oracle")

    on, off = results["reflow_on"], results["reflow_off"]
    fail = results["retrain_fail"]
    results["reflow_completed"] = (
        on["drift_stats"]["reflows_completed"] >= 1)
    results["degraded_modes_never_swap"] = (
        off["drift_stats"]["reflows_completed"] == 0
        and fail["drift_stats"]["reflows_completed"] == 0
        and fail["drift_stats"]["retrain_failures"] >= 1)
    results["reflow_improves_tail"] = (
        on["steady"]["read"]["p999_ns"] < off["steady"]["read"]["p999_ns"])
    results["reflow_improves_p50"] = (
        on["steady"]["read"]["p50_ns"] < off["steady"]["read"]["p50_ns"])
    # bounded stalls: the re-key piggybacks budgeted ticks on insert
    # calls, so the *median* insert call must stay within a small factor
    # of the no-reflow modes' (the max legitimately absorbs the one-time
    # jit compile of the training step; self-calibrating because an
    # absolute wall-clock gate would track the host, not the algorithm)
    stall_ref = max(off["drift_phase"]["p50_insert_call_s"],
                    fail["drift_phase"]["p50_insert_call_s"])
    results["bounded_insert_stalls"] = (
        on["drift_phase"]["p50_insert_call_s"] <= 10.0 * stall_ref
        and on["settle_phase"]["p50_insert_call_s"]
        <= 10.0 * max(off["settle_phase"]["p50_insert_call_s"],
                      fail["settle_phase"]["p50_insert_call_s"]))
    if assert_headline:
        assert results["reflow_completed"], \
            "re-flow never completed in reflow_on mode"
        assert results["degraded_modes_never_swap"], \
            "a degraded mode swapped the serving transform"
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    out = []
    for mode in MODES:
        r = results.get(mode)
        if not r or not r["steady"].get("read"):
            continue
        st = r["drift_stats"]
        out.append((f"perf_drift/{mode}",
                    r["steady"]["read"]["p50_ns"] / 1e3,
                    f"p999_us={r['steady']['read']['p999_ns'] / 1e3:.1f};"
                    f"score={st['last_score']:.2f};"
                    f"reflows={st['reflows_completed']};"
                    f"improves_tail={results.get('reflow_improves_tail')}"))
    return out
