"""Dynamic resharding under skew: hot-shard split with online boundary
migration, p99 recovery vs a balanced baseline (DESIGN.md §18).

The pathology: zipfian-ish point traffic concentrates ~60% of reads on
one of P=4 shards while an insert storm lands in the same hot key
range.  The hot shard's tiers fatten and its probe-window ratchets
climb, so the per-key read tail diverges from what the same host serves
under balanced traffic.  §18's answer is a *localized* migration: the
load-weighted re-partition splits the hot range across the window's
slots and folds fresh candidates while the untouched shards keep
serving.  Four modes over identically-keyed workloads:

* **balanced** — the same insert volume and read count, spread
  uniformly: the reference tail the migration is trying to get back to.
* **migrate_on** — the ReshardManager detects the hot shard from the
  decayed load gauges and swaps a re-partitioned window in mid-traffic.
* **migrate_off** — ``ReshardConfig(migrate=False)``: detection
  telemetry only; the skewed boundaries (and the fat hot shard) persist
  into the steady window.
* **migrate_fail** — every migration attempt dies mid-fold (injected
  §16 fault): the episode must roll back, back off, and keep serving
  the old boundaries with zero wrong answers.

Every lookup batch in every phase is cross-checked against a dict
oracle; any ``wrong`` fails the run.  Headline:
``post_migration_within_1_5x`` — the migrate-on steady hot-traffic p99
lands within 1.5x of the balanced baseline.  Emits machine-readable
``BENCH_resharding.json``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.drift import ReshardConfig
from repro.core.flat_afli import FlatAFLIConfig
from repro.core.nfl import NFL, NFLConfig
from repro.data.datasets import make_dataset
from repro.serve import faults

DEFAULT_OUT = "BENCH_resharding.json"
MODES = ("balanced", "migrate_on", "migrate_off", "migrate_fail")
HOT_READ_FRAC = 0.6     # share of reads aimed at the hot shard
N_SHARDS = 4


def _pct(lat_ns: np.ndarray):
    if not len(lat_ns):
        return {}
    return {
        "p50_ns": float(np.percentile(lat_ns, 50)),
        "p99_ns": float(np.percentile(lat_ns, 99)),
        "p999_ns": float(np.percentile(lat_ns, 99.9)),
        "max_ns": float(lat_ns.max()),
    }


def _reshard_cfg(mode: str) -> ReshardConfig:
    return ReshardConfig(
        enabled=True, migrate=(mode != "migrate_off"), hot_frac=2.0,
        min_load=256.0, min_keys=1024, check_every=512,
        # the first trigger fires early in the storm; a moderate
        # cooldown lets a corrective episode re-partition once the full
        # storm has landed (the settle phase drains any in-flight
        # migration before the timed steady window)
        cooldown_keys=8192, load_window_keys=4096)


def _draw(rng, hot_pool, cold_pool, n, skewed: bool):
    """One read batch under the mode's traffic law."""
    if not skewed:
        allp = np.concatenate([hot_pool, cold_pool])
        return rng.choice(allp, min(n, allp.shape[0]), replace=False)
    n_hot = int(n * HOT_READ_FRAC)
    return np.concatenate([
        rng.choice(hot_pool, min(n_hot, hot_pool.shape[0]), replace=False),
        rng.choice(cold_pool, min(n - n_hot, cold_pool.shape[0]),
                   replace=False)])


def _shard_spread(nfl) -> dict:
    """Routed-point balance over the window since the last reset: the
    hot shard's share of traffic and the max/mean spread."""
    per = nfl.dispatch_stats(reset=True)["router"]["per_shard_points"]
    tot = float(sum(per)) or 1.0
    shares = [p / tot for p in per]
    return {"per_shard_points": [int(p) for p in per],
            "max_share": max(shares),
            "spread": max(shares) * len(per)}   # 1.0 = perfectly even


def _storm_phase(nfl, oracle, hot_pool, cold_pool, storm, rng,
                 batch_size: int, skewed: bool):
    """Insert the storm, interleaving oracle-checked reads drawn by the
    mode's traffic law."""
    read_lat, wrong, n_ops = [], 0, 0
    t0_run = time.perf_counter()
    for i in range(0, storm.shape[0], batch_size):
        k = storm[i:i + batch_size]
        v = np.arange(k.shape[0], dtype=np.int64) + 1_000_000_000 + i
        nfl.insert_batch(k, v)
        oracle.update(zip(k.tolist(), v.tolist()))
        q = _draw(rng, hot_pool, cold_pool, batch_size, skewed)
        t0 = time.perf_counter()
        res = nfl.lookup_batch(q)
        read_lat.append((time.perf_counter() - t0) / q.shape[0])
        exp = np.array([oracle[kk] for kk in q.tolist()])
        wrong += int((res != exp).sum())
        n_ops += k.shape[0] + q.shape[0]
    return {
        "n_ops": n_ops,
        "run_s": time.perf_counter() - t0_run,
        "read": _pct(np.asarray(read_lat) * 1e9),
        "wrong": wrong,
    }


def _steady_phase(nfl, oracle, hot_pool, cold_pool, rng, n_batches: int,
                  batch_size: int, skewed: bool):
    """Read-only steady window, best-of-3 per batch (same rationale as
    ``bench_drift``: systematic per-batch probe cost, not host spikes)."""
    for _ in range(4):   # unmeasured: one-time upload/trace after a swap
        nfl.lookup_batch(_draw(rng, hot_pool, cold_pool, batch_size,
                               skewed))
    nfl.dispatch_stats(reset=True)   # steady-window routing counters
    lat, wrong = [], 0
    t0_run = time.perf_counter()
    for _ in range(n_batches):
        q = _draw(rng, hot_pool, cold_pool, batch_size, skewed)
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            res = nfl.lookup_batch(q)
            best = min(best, time.perf_counter() - t0)
        lat.append(best / q.shape[0])
        exp = np.array([oracle[kk] for kk in q.tolist()])
        wrong += int((res != exp).sum())
    t_run = time.perf_counter() - t0_run
    n = n_batches * 3 * batch_size
    return {
        "n_reads": n,
        "run_s": t_run,
        "throughput_mops": n / t_run / 1e6,
        "read": _pct(np.asarray(lat) * 1e9),
        "wrong": wrong,
        "routing": _shard_spread(nfl),
    }


def _run_mode(mode: str, base, *, n_storm: int, n_settle_batches: int,
              n_steady: int, batch_size: int, seed: int):
    pv = np.arange(len(base), dtype=np.int64)
    nfl = NFL(NFLConfig(
        backend="flat", shards=N_SHARDS, force_flow=False,
        flat_index=FlatAFLIConfig(fold_step_keys=2048),
        reshard=_reshard_cfg(mode)))
    t0 = time.perf_counter()
    nfl.bulkload(base, pv)
    t_load = time.perf_counter() - t0
    idx = nfl.index
    b0 = idx.boundaries.copy()
    oracle = dict(zip(base.tolist(), pv.tolist()))
    skewed = mode != "balanced"

    # the hot shard is slot 0: its domain is [-inf, B[0])
    hot_pool = base[base.astype(np.float32) < b0[0]]
    cold_pool = base[base.astype(np.float32) >= b0[0]]
    rng = np.random.default_rng(seed + 1)
    # the storm lands where the reads are hot; balanced jitters the
    # whole keyset instead, so write load spreads like key mass and the
    # reference mode never crosses the hot-shard threshold
    src = hot_pool if skewed else base
    storm = np.unique(rng.choice(src, n_storm)
                      * (1.0 + rng.uniform(1e-6, 1e-4, n_storm)))
    storm = storm[~np.isin(storm, base)]
    rng.shuffle(storm)   # unique() sorts: unshuffled batches would sweep
    # the key space shard-by-shard and spoof a hot-WRITE shard everywhere

    # warm the read-path shape buckets, then zero the phase counters
    nfl.lookup_batch(rng.choice(base, batch_size, replace=False))
    nfl.dispatch_stats(reset=True)

    def _go():
        storm_res = _storm_phase(nfl, oracle, hot_pool, cold_pool, storm,
                                 rng, batch_size, skewed)
        storm_res["routing"] = _shard_spread(nfl)
        # settle: identical unmeasured trickle in every mode — with
        # migration on this is where the episode completes and swaps
        for i in range(n_settle_batches + 400):
            if i >= n_settle_batches and nfl.index._reshard is None:
                break   # drained: no fold work rides the timed window
            q = _draw(rng, hot_pool, cold_pool, batch_size, skewed)
            res = nfl.lookup_batch(q)
            exp = np.array([oracle[kk] for kk in q.tolist()])
            storm_res["wrong"] += int((res != exp).sum())
        steady = _steady_phase(nfl, oracle, hot_pool, cold_pool, rng,
                               n_batches=max(n_steady // batch_size, 1),
                               batch_size=batch_size, skewed=skewed)
        return storm_res, steady

    if mode == "migrate_fail":
        with faults.inject(faults.FaultPlan(fail_reshard="fold"), nfl=nfl):
            storm_res, steady = _go()
    else:
        storm_res, steady = _go()

    rs = nfl.dispatch_stats()["reshard"]
    return {
        "bulkload_s": t_load,
        "storm_phase": storm_res,
        "steady": steady,
        "boundaries_moved": bool(not np.array_equal(idx.boundaries, b0)),
        "reshard_stats": {k: rs[k] for k in (
            "state", "checks", "resharding_episodes",
            "migrations_completed", "migrations_failed", "last_hot_shard",
            "cooldown_span")},
        "n_reshards": int(idx.n_reshards),
        "n_reshard_aborts": int(idx.n_reshard_aborts),
    }


def run(n_keys: int = 32_768, n_storm: int = 12_288,
        n_settle_batches: int = 48, n_steady: int = 16_384,
        batch_size: int = 256, out_json: str = DEFAULT_OUT,
        assert_headline: bool = True, assert_perf: bool = False):
    base = np.unique(make_dataset("lognormal", n_keys))
    results = {"workload": {
        "n_keys": int(base.shape[0]), "n_storm": n_storm,
        "n_settle_batches": n_settle_batches, "n_steady": n_steady,
        "batch_size": batch_size, "n_shards": N_SHARDS,
        "hot_read_frac": HOT_READ_FRAC, "dataset": "lognormal",
    }}
    for mode in MODES:
        results[mode] = _run_mode(
            mode, base, n_storm=n_storm,
            n_settle_batches=n_settle_batches, n_steady=n_steady,
            batch_size=batch_size, seed=7)
        r = results[mode]
        rs = r["reshard_stats"]
        print(f"[resharding {mode}] steady p50="
              f"{r['steady']['read'].get('p50_ns', 0) / 1e3:.2f}us p99="
              f"{r['steady']['read'].get('p99_ns', 0) / 1e3:.2f}us "
              f"spread={r['steady']['routing']['spread']:.2f} "
              f"episodes={rs['resharding_episodes']} "
              f"completed={rs['migrations_completed']} "
              f"failed={rs['migrations_failed']} "
              f"moved={r['boundaries_moved']} "
              f"wrong={r['storm_phase']['wrong']}+{r['steady']['wrong']}")
        wrong = r["storm_phase"]["wrong"] + r["steady"]["wrong"]
        if wrong:
            raise AssertionError(
                f"resharding {mode}: {wrong} lookups diverged from the "
                f"oracle")

    on, off = results["migrate_on"], results["migrate_off"]
    bal, fail = results["balanced"], results["migrate_fail"]
    results["migration_completed"] = (
        on["reshard_stats"]["migrations_completed"] >= 1
        and on["boundaries_moved"])
    results["off_mode_detects_but_never_moves"] = (
        off["reshard_stats"]["checks"] >= 1
        and off["reshard_stats"]["resharding_episodes"] == 0
        and not off["boundaries_moved"])
    results["fail_mode_backs_off_serving_old_boundaries"] = (
        fail["reshard_stats"]["migrations_failed"] >= 1
        and fail["reshard_stats"]["migrations_completed"] == 0
        and not fail["boundaries_moved"]
        and fail["n_reshard_aborts"] >= 1)
    # the acceptance headline: post-migration hot-traffic steady p99
    # within 1.5x of the balanced baseline
    results["post_migration_within_1_5x"] = (
        on["steady"]["read"]["p99_ns"]
        <= 1.5 * bal["steady"]["read"]["p99_ns"])
    # informational: what the skew costs without migration, and how the
    # swap rebalances per-shard routed load (spread 1.0 = perfectly even)
    results["off_over_balanced_p99"] = (
        off["steady"]["read"]["p99_ns"] / bal["steady"]["read"]["p99_ns"])
    results["on_over_balanced_p99"] = (
        on["steady"]["read"]["p99_ns"] / bal["steady"]["read"]["p99_ns"])
    results["migration_improves_spread"] = (
        on["steady"]["routing"]["max_share"]
        < off["steady"]["routing"]["max_share"])
    if assert_headline:
        assert results["migration_completed"], \
            "migrate_on never completed a migration"
        assert results["off_mode_detects_but_never_moves"], \
            "migrate_off moved boundaries (telemetry-only contract)"
        assert results["fail_mode_backs_off_serving_old_boundaries"], \
            "migrate_fail did not roll back to the old boundaries"
    # the 1.5x timing gate is noise-sensitive at smoke scale, so it is
    # opt-in (asserted when producing the committed full-size baseline;
    # recorded but not asserted in the verify.sh smoke, whose job is the
    # wrong=0 gate — wrong answers raise in-loop unconditionally)
    if assert_perf:
        assert results["post_migration_within_1_5x"], (
            f"post-migration p99 "
            f"{on['steady']['read']['p99_ns'] / 1e3:.2f}us not within "
            f"1.5x of balanced "
            f"{bal['steady']['read']['p99_ns'] / 1e3:.2f}us")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    out = []
    for mode in MODES:
        r = results.get(mode)
        if not r or not r["steady"].get("read"):
            continue
        rs = r["reshard_stats"]
        out.append((f"perf_resharding/{mode}",
                    r["steady"]["read"]["p50_ns"] / 1e3,
                    f"p99_us={r['steady']['read']['p99_ns'] / 1e3:.2f};"
                    f"spread={r['steady']['routing']['spread']:.2f};"
                    f"completed={rs['migrations_completed']};"
                    f"within_1_5x="
                    f"{results.get('post_migration_within_1_5x')}"))
    return out
