"""Paper Table 2: per-key NF inference latency vs batch size and flow size.

Two backends: the jnp host path and the fused Pallas kernel (interpret mode
on CPU; on TPU the same call compiles to Mosaic).  The paper's headline —
per-key cost collapses with batching — must reproduce on both.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.feature import expand_features
from repro.core.flow import FlowConfig, transform_keys
from repro.core.train_flow import FlowTrainConfig, train_flow
from repro.data.datasets import make_dataset
from repro.kernels import ops

FLOW_SIZES = {
    "2H2L": FlowConfig(dim=2, hidden=2, layers=2),
    "2H4L": FlowConfig(dim=2, hidden=2, layers=4),
    "4H3L": FlowConfig(dim=2, hidden=4, layers=3),
}
BATCHES = (1, 8, 32, 128, 256, 1024, 2048)


def _time_per_key(fn, keys, batch, repeats=5):
    # warmup + best-of timing, per the paper's averaged-latency methodology
    fn(keys[:batch])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(keys[:batch])
        best = min(best, (time.perf_counter() - t0) / batch)
    return best * 1e9


def run(n_keys: int = 10_000) -> List[Tuple]:
    keys = make_dataset("lognormal", n_keys)
    rows_out = []
    for name, cfg in FLOW_SIZES.items():
        params, norm, _ = train_flow(keys, cfg, FlowTrainConfig(epochs=1))

        host = lambda ks: transform_keys(params, norm, ks, cfg)
        kern = lambda ks: ops.nf_transform_keys(params, norm, ks, cfg)
        for batch in BATCHES:
            ns_host = _time_per_key(host, keys, batch)
            ns_kern = _time_per_key(kern, keys, batch)
            rows_out.append((name, batch, ns_host, ns_kern))
            print(f"[table2] {name} batch={batch:5d} "
                  f"host={ns_host:10.1f} ns/key  pallas={ns_kern:10.1f} ns/key")
    return rows_out


def rows(results):
    return [(f"table2_nf_latency/{name}/b{batch}", ns_host / 1e3,
             f"pallas_ns={ns_kern:.0f}")
            for name, batch, ns_host, ns_kern in results]
