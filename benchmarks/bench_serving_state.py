"""Zero-repack serving: steady-state tails + retrace/upload telemetry.

The NFL paper's headline claim is *lowest tail latency*, and the mixed
workload used to give the serving harness away: reads at p50 ~21us but
p99 ~16ms — a ~750x blowup paid not in the index but in per-call pool
repacks, re-uploads, and mid-workload XLA retraces whenever a tier
length crossed a lane-padded shape.  DESIGN.md §11's ServingState makes
the steady state pay only for the kernel; this bench *measures* that
claim instead of inferring it:

* **warmup window** — drives the 80/20 mix long enough to prime every
  shape bucket (delta growth ladder, at least one incremental fold
  swap, all read-batch buckets), then zeroes the dispatch and serving
  counters;
* **measurement window** — same mix, now asserting the §11 properties
  directly: ``retrace_count == 0`` (no serving dispatch grew a jit
  cache), ``tier_repacks == 0`` (no full-pool host repack, only
  bounded device prefix writes), ``host_tier_probes == 0``, and the
  steady-state read ``p99/p50 <= 10`` gate;
* **legacy comparison** — the identical workload with
  ``bucketed_serving=False`` (the pre-§11 behavior: per-mutation tier
  repacks, exact statics free to shrink), so the before/after tails and
  retrace counts land in the same JSON.

Every lookup batch is cross-checked against a dict oracle
(last-write-wins); ``wrong`` must be 0.  Emits machine-readable
``BENCH_serving_state.json``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.flat_afli import FlatAFLIConfig
from repro.core.flow import FlowConfig
from repro.core.nfl import NFL, NFLConfig
from repro.core.train_flow import FlowTrainConfig
from repro.data.datasets import make_dataset

DEFAULT_OUT = "BENCH_serving_state.json"
WRITE_FRAC = 0.20  # the ISSUE-3 acceptance mix (80/20)


def _pct(lat_ns: np.ndarray):
    if not len(lat_ns):
        return {}
    return {
        "p50_ns": float(np.percentile(lat_ns, 50)),
        "p99_ns": float(np.percentile(lat_ns, 99)),
        "p999_ns": float(np.percentile(lat_ns, 99.9)),
        "max_ns": float(lat_ns.max()),
    }


class _MixDriver:
    """Deterministic 80/20 op-stream against one NFL index + dict oracle.

    One instance drives both the warmup and the measurement window, so
    the measured phase continues the exact workload state (tier fills,
    folds in flight) the warmup primed."""

    def __init__(self, nfl, keys, insert_pool, seed: int):
        self.nfl = nfl
        self.keys = keys
        self.insert_pool = insert_pool
        self.rng = np.random.default_rng(seed)
        self.oracle = {}
        self.next_ins = 0
        self.high_water = 0
        self.ops_done = 0

    def seed_oracle(self, keys, payloads):
        for k, p in zip(keys, payloads):
            self.oracle[k] = p

    def run(self, n_ops: int, batch_size: int):
        """Drive ``n_ops`` operations; returns the phase result dict.
        Serving time only — oracle bookkeeping stays outside every timed
        window."""
        read_lat, ins_lat, ins_call_s = [], [], []
        wrong = 0
        t0_run = time.perf_counter()
        done = 0
        while done < n_ops:
            is_write = self.rng.random(batch_size) < WRITE_FRAC
            n_w = int(is_write.sum())
            n_r = batch_size - n_w
            q = None
            if n_r:
                q = self.rng.choice(self.keys, n_r)
                if self.high_water:
                    tiered = self.rng.random(n_r) < 0.5
                    q[tiered] = self.rng.choice(
                        self.insert_pool[:self.high_water],
                        int(tiered.sum()))
            if n_w and self.next_ins + n_w > len(self.insert_pool):
                self.next_ins = 0  # wrap: re-inserts hit last-write-wins
            ins_k = self.insert_pool[self.next_ins:self.next_ins + n_w]
            ins_v = (np.arange(n_w, dtype=np.int64) + 1_000_000_000
                     + self.ops_done + done)
            self.next_ins += n_w
            res = None
            if q is not None and len(q):
                t0 = time.perf_counter()
                res = self.nfl.lookup_batch(q)
                read_lat.append((time.perf_counter() - t0) / len(q))
            if n_w:
                t0 = time.perf_counter()
                self.nfl.insert_batch(ins_k, ins_v)
                t_ins = time.perf_counter() - t0
                ins_call_s.append(t_ins)
                ins_lat.append(t_ins / n_w)
            if res is not None:
                exp = np.array([self.oracle.get(k, -1) for k in q])
                wrong += int((res != exp).sum())
            if n_w:
                for k, v in zip(ins_k, ins_v):
                    self.oracle[k] = v
                self.high_water = max(self.high_water, self.next_ins)
            done += batch_size
        t_run = time.perf_counter() - t0_run
        self.ops_done += done
        read_ns = np.asarray(read_lat) * 1e9
        out = {
            "n_ops": done,
            "run_s": t_run,
            "throughput_mops": done / t_run / 1e6,
            "read": _pct(read_ns),
            "insert": _pct(np.asarray(ins_lat) * 1e9),
            "max_insert_call_s": float(max(ins_call_s)) if ins_call_s
            else 0.0,
            "wrong": wrong,
        }
        if out["read"]:
            out["read_p99_over_p50"] = (out["read"]["p99_ns"]
                                        / max(out["read"]["p50_ns"], 1.0))
        return out


def _run_variant(keys, insert_pool, *, bucketed: bool, n_warmup: int,
                 n_ops: int, batch_size: int, seed: int):
    """Bulkload + warmup + measured window for one serving mode."""
    pv = np.arange(len(keys), dtype=np.int64)
    nfl = NFL(NFLConfig(
        flow=FlowConfig(dim=3), flow_train=FlowTrainConfig(epochs=1),
        backend="flat",
        flat_index=FlatAFLIConfig(rebuild_frac=0.005, delta_cap=256,
                                  fold_step_keys=8192,
                                  bucketed_serving=bucketed)))
    t0 = time.perf_counter()
    nfl.bulkload(keys, pv)
    t_load = time.perf_counter() - t0

    driver = _MixDriver(nfl, keys, insert_pool, seed)
    driver.seed_oracle(keys, pv)
    # ---- warmup: prime every shape bucket and the fold machinery,
    # then zero the telemetry so the measured window is steady state
    warm = driver.run(n_warmup, batch_size)
    nfl.dispatch_stats(reset=True)
    warm["compiles"] = None  # counters were live during bulkload too;
    #                          per-phase counts start at the measure window
    meas = driver.run(n_ops, batch_size)
    st = nfl.stats()
    tele = nfl.dispatch_stats()
    disp = tele["dispatch"]
    serving = tele["serving"]
    meas.update({
        "retrace_count": disp["retrace_count"],
        "dispatch_count": disp["dispatch_count"],
        "fallback_count": disp["fallback_count"],
        "host_tier_probes": int(st["n_host_tier_probes"]),
        "tier_repacks": serving["tier_repacks"],
        "tier_uploads": serving["tier_uploads"],
        "tier_upload_bytes": serving["tier_upload_bytes"],
        "tree_packs": serving["tree_packs"],
        "n_rebuilds": int(st["n_rebuilds"]),
        "fold_active_at_end": bool(st["fold_active"]),
    })
    return {"bulkload_s": t_load, "warmup": warm, "measure": meas,
            "serving_stats": serving}


def run(n_keys: int = 65_536, n_ops: int = 8_192, n_warmup: int = 6_144,
        batch_size: int = 256, out_json: str = DEFAULT_OUT,
        legacy: bool = True):
    all_keys = make_dataset("lognormal", int(n_keys * 1.5))
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(all_keys))
    keys = np.ascontiguousarray(all_keys[perm[:n_keys]])
    insert_pool = np.ascontiguousarray(all_keys[perm[n_keys:]])

    results = {"workload": {"n_keys": int(len(keys)),
                            "n_insertable": int(len(insert_pool)),
                            "mix": "80/20", "n_warmup": n_warmup,
                            "n_ops": n_ops, "batch_size": batch_size,
                            "dataset": "lognormal"}}
    results["serving_state"] = _run_variant(
        keys, insert_pool, bucketed=True, n_warmup=n_warmup, n_ops=n_ops,
        batch_size=batch_size, seed=77)
    if legacy:
        results["legacy"] = _run_variant(
            keys, insert_pool, bucketed=False, n_warmup=n_warmup,
            n_ops=n_ops, batch_size=batch_size, seed=77)

    m = results["serving_state"]["measure"]
    results["zero_retraces"] = m["retrace_count"] == 0
    results["zero_host_repacks"] = m["tier_repacks"] == 0
    results["read_tail_bounded"] = m.get("read_p99_over_p50",
                                         float("inf")) <= 10.0
    for name in ("serving_state",) + (("legacy",) if legacy else ()):
        r = results[name]["measure"]
        print(f"[serving_state {name}] read p50="
              f"{r['read'].get('p50_ns', 0)/1e3:.1f}us p99="
              f"{r['read'].get('p99_ns', 0)/1e3:.1f}us "
              f"(x{r.get('read_p99_over_p50', float('nan')):.1f}) "
              f"retraces={r['retrace_count']} "
              f"repacks={r['tier_repacks']} "
              f"uploads={r['tier_uploads']} wrong={r['wrong']} "
              f"rebuilds={r['n_rebuilds']}")
        if r["wrong"]:
            raise AssertionError(
                f"serving_state {name}: {r['wrong']} lookups diverged "
                "from the dict oracle")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    return results


def rows(results) -> List[Tuple]:
    out = []
    for name in ("serving_state", "legacy"):
        if name not in results:
            continue
        r = results[name]["measure"]
        if not r.get("read"):
            continue
        out.append((f"perf_serving_state/{name}",
                    r["read"]["p50_ns"] / 1e3,
                    f"read_p99_over_p50="
                    f"{r.get('read_p99_over_p50', float('nan')):.1f};"
                    f"retraces={r['retrace_count']};"
                    f"repacks={r['tier_repacks']}"))
    return out
