"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

compute  = HLO_FLOPs_per_device * depth_correction / peak_FLOPs
memory   = HLO_bytes_per_device * depth_correction / HBM_bw
collective = wire_bytes_per_device * depth_correction / link_bw

cost_analysis counts scan bodies once; utils.roofline derives the per-layer
correction from the artifact metadata (layer count vs. probe depth).
"""

from __future__ import annotations

import json
import os
from typing import List

from repro.utils.roofline import analyze_artifact, ARTIFACT_DIR


def run(mesh: str | None = None) -> List[dict]:
    rows_out = []
    if not os.path.isdir(ARTIFACT_DIR):
        print("[roofline] no dry-run artifacts; run repro.launch.dryrun first")
        return rows_out
    for fn in sorted(os.listdir(ARTIFACT_DIR)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(ARTIFACT_DIR, fn)) as f:
            art = json.load(f)
        if mesh and art["mesh"] != mesh:
            continue
        row = analyze_artifact(art)
        rows_out.append(row)
        print(f"[roofline] {row['arch']:22s} {row['shape']:11s} "
              f"{row['mesh']:8s} compute={row['compute_s']*1e3:9.3f}ms "
              f"memory={row['memory_s']*1e3:9.3f}ms "
              f"coll={row['collective_s']*1e3:9.3f}ms "
              f"bound={row['bound']:10s} useful={row['useful_frac']:6.1%}")
    return rows_out


def rows(results):
    return [(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             r[r['bound'] + '_s'] * 1e6,
             f"bound={r['bound']};useful={r['useful_frac']:.3f}")
            for r in results]
