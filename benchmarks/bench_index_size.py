"""Paper Fig. 11: index size after the write-heavy running phase."""

from __future__ import annotations

from typing import List

from repro.data.datasets import make_dataset

from benchmarks.common import INDEXES, run_workload


def run(n_keys: int = 100_000, datasets=("longlat", "facebook"),
        indexes=None):
    indexes = indexes or INDEXES
    results = []
    for ds in datasets:
        keys = make_dataset(ds, n_keys)
        per_ds = {}
        for index in indexes:
            r = run_workload(index, keys, "write_heavy", n_ops=20_000)
            r.dataset = ds
            per_ds[index] = r
            results.append(r)
        base = per_ds["alex"].size_bytes or 1
        for index, r in per_ds.items():
            print(f"[fig11] {ds:11s} {index:6s} {r.size_bytes/1e6:8.2f} MB "
                  f"({r.size_bytes/base:5.2f}x ALEX)")
    return results


def rows(results):
    return [(f"fig11_size/{r.dataset}/{r.index}", float(r.size_bytes) / 1e6,
             f"{r.size_bytes}B") for r in results]
