"""§Perf hillclimb 3: probe-path batch-size sweep.

Compares the paper-style host tree walk (AFLI python probe) against the
TPU-native vectorized FlatAFLI probe across request batch sizes — the
crossover shows where batched device probes pay off (the paper's own
Table 2 insight, applied to the index probe instead of the NF).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.afli import AFLI
from repro.core.flat_afli import FlatAFLI
from repro.data.datasets import make_dataset

BATCHES = (64, 256, 1024, 8192, 65536)


def _best_ns_per_key(fn, keys, batch, repeats=5):
    fn(keys[:batch])
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(keys[:batch])
        best = min(best, (time.perf_counter() - t0) / batch)
    return best * 1e9


def run(n_keys: int = 200_000) -> List[Tuple]:
    keys = make_dataset("lognormal", n_keys)
    pv = np.arange(len(keys), dtype=np.int64)

    tree = AFLI()
    tree.bulkload(keys, pv)

    flat = FlatAFLI()
    flat.build(keys, pv)

    def tree_lookup(ks):
        lk = tree.lookup
        return [lk(float(k)) for k in ks]

    rows_out = []
    rng = np.random.default_rng(0)
    probe_keys = rng.choice(keys, size=max(BATCHES), replace=True)
    for b in BATCHES:
        ns_tree = _best_ns_per_key(tree_lookup, probe_keys, b)
        ns_flat = _best_ns_per_key(flat.lookup_batch, probe_keys, b)
        rows_out.append((b, ns_tree, ns_flat))
        print(f"[probe_batch] batch={b:6d} tree={ns_tree:9.1f} ns/key "
              f"flat={ns_flat:9.1f} ns/key  speedup={ns_tree/ns_flat:5.2f}x")
    return rows_out


def rows(results):
    return [(f"perf_probe_batch/b{b}", ns_flat / 1e3,
             f"tree_ns={ns_tree:.0f};speedup={ns_tree/ns_flat:.2f}")
            for b, ns_tree, ns_flat in results]
