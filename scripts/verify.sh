#!/usr/bin/env bash
# Pre-merge verification: tier-1 test suite + a seconds-scale smoke of
# the two serving-path benchmarks (fused read path, mixed write path),
# so a perf-path regression in either dispatch route is caught before
# it lands.  Usage: scripts/verify.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q "$@"

echo "== serving-path smoke (fused + mixed) =="
python -m benchmarks.run --smoke --only fused --only mixed

echo "verify.sh: OK"
