#!/usr/bin/env bash
# Pre-merge verification: docs checks (README/API snippets execute,
# DESIGN.md § references + relative links resolve), lint, the §15
# kernel-contract checker (static analysis + fixture self-test), the
# tier-1 test suite, and a seconds-scale smoke of the serving-path benchmarks
# (fused read path, mixed write path, §11 serving state, §12 range
# scans, §14 drift re-flow, §16 SLO front-end incl. injected faults,
# §17 HBM-streaming tier, §18 dynamic resharding),
# so a doc or perf-path regression in any dispatch route is caught
# before it lands.
# Any "wrong" count > 0 in an emitted BENCH JSON fails the run.
#
# Usage:
#   scripts/verify.sh [extra pytest args]          # full tier
#   scripts/verify.sh --quick [extra pytest args]  # hard wall-clock
#       budget per phase (VERIFY_QUICK_BUDGET_S, default 1500s): tier-1
#       tests + smoke benches, then the bench-JSON correctness gate
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

QUICK=0
if [[ "${1:-}" == "--quick" ]]; then
  QUICK=1
  shift
fi
BUDGET="${VERIFY_QUICK_BUDGET_S:-1500}"
run_phase() {
  if [[ "$QUICK" == 1 ]]; then
    timeout "$BUDGET" "$@"
  else
    "$@"
  fi
}

echo "== docs check (snippets + DESIGN.md refs + links) =="
run_phase python scripts/check_docs.py

echo "== lint (ruff or builtin AST fallback) =="
run_phase python scripts/lint.py

echo "== kernel contracts (§15 static analysis + fixture self-test) =="
run_phase python scripts/check_kernels.py

echo "== tier-1 test suite =="
run_phase python -m pytest -x -q "$@"

echo "== serving-path smoke (fused + mixed + serving state + range) =="
run_phase python -m benchmarks.run --smoke --only fused --only mixed \
  --only serving

echo "== streamed smoke (§17 HBM-streaming tier, pool/budget sweep) =="
run_phase python -m benchmarks.run --smoke --only streamed
# the range and drift smokes emit BENCH_*.smoke.json so the correctness
# gate below sees their wrong counts; the EXIT trap removes them on
# every outcome — only the committed full-size baselines persist
trap 'rm -f BENCH_range_scan.smoke.json BENCH_drift.smoke.json BENCH_service.smoke.json BENCH_resharding.smoke.json' EXIT
run_phase python -m benchmarks.run --smoke --only range

echo "== drift smoke (§14 re-flow on/off/forced-failure) =="
run_phase python -m benchmarks.run --smoke --only drift

echo "== service smoke (§16 SLO front-end + injected faults) =="
run_phase python -m benchmarks.run --smoke --only service

echo "== resharding smoke (§18 hot-shard migration on/off/forced-failure) =="
run_phase python -m benchmarks.run --smoke --only resharding

echo "== bench JSON correctness gate (wrong > 0 fails) =="
python - <<'PY'
import glob
import json
import sys

bad = []


def scan(obj, path):
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k == "wrong" and isinstance(v, (int, float)) and v > 0:
                bad.append(f"{path}/{k}={v}")
            else:
                scan(v, f"{path}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            scan(v, f"{path}[{i}]")


for f in sorted(glob.glob("BENCH_*.json")):
    with open(f) as fh:
        scan(json.load(fh), f)
if bad:
    print("verify.sh: wrong > 0 in emitted bench JSON:")
    for b in bad:
        print("  " + b)
    sys.exit(1)
print("bench JSONs clean")
PY

echo "verify.sh: OK"
