#!/usr/bin/env python
"""Docs CI check (run from scripts/verify.sh).

Three gates, all fast enough for every pre-merge run:

1. **Snippet execution** — every fenced ```python block in README.md
   and docs/API.md runs top to bottom (one shared namespace per file,
   blocks in document order, so later snippets may build on earlier
   ones).  A fence info-string containing ``no-run`` skips a block.
   Docs that drift from the API fail the merge gate instead of rotting.

2. **DESIGN.md section references** — every ``§N`` citation in the
   Python sources and the markdown docs (the repo convention for
   pointing at DESIGN.md) must name a section that actually exists.
   Dotted references (``§3.2.2``) and ``paper §...`` forms cite the
   NFL paper, not DESIGN.md, and are ignored.

3. **Relative links** — ``[text](path)`` links in README.md and
   docs/API.md must point at files that exist (external URLs and
   in-page anchors are ignored).

Exit status is nonzero on any failure; failures are listed per gate.
"""

from __future__ import annotations

import os
import re
import sys
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", os.path.join("docs", "API.md")]

FENCE_RE = re.compile(r"^```(\S*)[^\n]*\n(.*?)^```", re.M | re.S)
# a DESIGN ref is an undotted §<int> not preceded by "paper "
SECTION_RE = re.compile(r"(paper\s+|Paper\s+)?§(\d+)(\.\d)?")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")


def design_sections() -> set:
    path = os.path.join(ROOT, "DESIGN.md")
    with open(path) as f:
        text = f.read()
    return {int(m.group(1)) for m in re.finditer(r"^## §(\d+)\b", text,
                                                 re.M)}


def check_snippets() -> list:
    failures = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        with open(path) as f:
            text = f.read()
        namespace: dict = {"__name__": f"docs_snippet:{doc}"}
        n = 0
        for m in FENCE_RE.finditer(text):
            info, body = m.group(1), m.group(2)
            if info != "python" or "no-run" in m.group(0).split("\n")[0]:
                continue
            n += 1
            line = text[:m.start()].count("\n") + 2
            try:
                code = compile(body, f"{doc}:snippet@L{line}", "exec")
                exec(code, namespace)
            except Exception:
                tb = traceback.format_exc(limit=3)
                failures.append(f"{doc} snippet at line {line} failed:\n"
                                f"{tb}")
        print(f"  {doc}: {n} python snippet(s) executed")
    return failures


def check_section_refs() -> list:
    sections = design_sections()
    failures = []
    py_files = []
    for sub in ("src", "benchmarks", "tests", "examples", "scripts"):
        for dirpath, _dirs, files in os.walk(os.path.join(ROOT, sub)):
            py_files += [os.path.join(dirpath, f) for f in files
                         if f.endswith(".py")]
    targets = py_files + [os.path.join(ROOT, d) for d in DOCS]
    n_refs = 0
    for path in targets:
        with open(path) as f:
            text = f.read()
        for m in SECTION_RE.finditer(text):
            if m.group(1) or m.group(3):  # "paper §..." or dotted
                continue
            n_refs += 1
            num = int(m.group(2))
            if num not in sections:
                line = text[:m.start()].count("\n") + 1
                rel = os.path.relpath(path, ROOT)
                failures.append(
                    f"{rel}:{line}: cites DESIGN.md §{num}, which does "
                    f"not exist (sections: {sorted(sections)})")
    print(f"  {n_refs} DESIGN.md § references checked against "
          f"{len(sections)} sections")
    return failures


def check_links() -> list:
    failures = []
    n = 0
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        base = os.path.dirname(path)
        with open(path) as f:
            text = f.read()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if "://" in target or target.startswith("mailto:"):
                continue
            n += 1
            if not os.path.exists(os.path.join(base, target)):
                line = text[:m.start()].count("\n") + 1
                failures.append(f"{doc}:{line}: broken link -> {target}")
    print(f"  {n} relative link(s) checked")
    return failures


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    failures = []
    print("== docs check: snippet execution ==")
    failures += check_snippets()
    print("== docs check: DESIGN.md section references ==")
    failures += check_section_refs()
    print("== docs check: relative links ==")
    failures += check_links()
    if failures:
        print(f"\ncheck_docs: {len(failures)} failure(s)")
        for f in failures:
            print("  " + f.replace("\n", "\n    "))
        return 1
    print("check_docs: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
