#!/usr/bin/env python
"""Lint gate for the serving-path sources (DESIGN.md §15 satellite).

Runs ``ruff check`` (config: ruff.toml) over
``src/repro/{analysis,core,kernels}`` when ruff is installed.  The
hermetic CI image may not ship it, so absent ruff this falls back to a
built-in AST pass covering the highest-signal pyflakes subset:

- **F401** — module-level import never used (``__all__`` re-exports
  and ``_``-prefixed names excused);
- **F811** — the same name imported twice in one scope;
- **E722** — bare ``except:`` (swallows ``KeyboardInterrupt`` and, per
  the §15 lock-discipline rule, would swallow ``LockDisciplineError``).

Exit status is nonzero on any finding; findings are ``file:line code
message`` so editors and CI render them alike.
"""

from __future__ import annotations

import ast
import os
import shutil
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TARGETS = [os.path.join("src", "repro", d)
           for d in ("analysis", "core", "kernels")]


def _iter_py_files():
    for target in TARGETS:
        for dirpath, _dirs, files in os.walk(os.path.join(ROOT, target)):
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


# --------------------------------------------------- AST fallback pass
def _import_bindings(node):
    """(name, lineno) pairs an import statement binds in its scope."""
    out = []
    if isinstance(node, ast.Import):
        for alias in node.names:
            name = alias.asname or alias.name.split(".")[0]
            out.append((name, node.lineno))
    elif isinstance(node, ast.ImportFrom):
        if node.module == "__future__":
            return out  # future imports act by existing, never by use
        for alias in node.names:
            if alias.name == "*":
                continue
            out.append((alias.asname or alias.name, node.lineno))
    return out


def _used_names(tree) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # the root of a dotted use is a Name and is caught above;
            # nothing extra needed, but keep the branch for clarity
            pass
    return used


def _dunder_all(tree) -> set:
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)):
            try:
                return set(ast.literal_eval(node.value))
            except (ValueError, TypeError):
                return set()
    return set()


def _check_file(path: str) -> list:
    rel = os.path.relpath(path, ROOT)
    with open(path) as fh:
        src = fh.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [f"{rel}:{e.lineno or 0} E999 syntax error: {e.msg}"]

    findings = []
    exported = _dunder_all(tree)
    used = _used_names(tree)
    is_init = os.path.basename(path) == "__init__.py"

    # E722 everywhere
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            findings.append(f"{rel}:{node.lineno} E722 bare `except:`")

    # F401 / F811 per scope (module body + each function/class body)
    scopes = [tree.body]
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            scopes.append(node.body)
    for scope in scopes:
        seen: dict = {}
        for stmt in scope:
            for name, lineno in _import_bindings(stmt):
                if name in seen:
                    findings.append(
                        f"{rel}:{lineno} F811 `{name}` reimported "
                        f"(first import at line {seen[name]})")
                seen[name] = lineno
                if scope is tree.body and not name.startswith("_") \
                        and name not in used and name not in exported \
                        and not is_init:
                    findings.append(
                        f"{rel}:{lineno} F401 `{name}` imported but "
                        "unused")
    return findings


def run_fallback() -> int:
    findings = []
    n = 0
    for path in _iter_py_files():
        n += 1
        findings.extend(_check_file(path))
    print(f"lint (builtin AST fallback): {n} file(s) checked")
    for f in sorted(findings):
        print("  " + f)
    if findings:
        print(f"lint: {len(findings)} finding(s)")
        return 1
    print("lint: OK")
    return 0


def main() -> int:
    ruff = shutil.which("ruff")
    if ruff:
        cmd = [ruff, "check", "--config",
               os.path.join(ROOT, "ruff.toml")] + \
              [os.path.join(ROOT, t) for t in TARGETS]
        print("lint (ruff):", " ".join(cmd[1:]))
        return subprocess.call(cmd)
    return run_fallback()


if __name__ == "__main__":
    sys.exit(main())
