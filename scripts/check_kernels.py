#!/usr/bin/env python
"""CI gate for the kernel contracts (DESIGN.md §15): thin wrapper over
``python -m repro.analysis`` so verify.sh has a stable entry point.

Runs all three contract families (static jaxpr/HLO checks, the
retrace-budget lattice drive, the VMEM proof) against the reviewed
allowlist in ``scripts/kernel_contracts_allow.txt``, then the fixture
self-test (every deliberately-broken kernel must still be caught).
Exits nonzero on any unallowlisted blocking finding or missed fixture.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    rc = main(sys.argv[1:])
    if rc == 0 and not sys.argv[1:]:
        # default CI invocation also self-tests the checker
        rc = main(["--fixtures"])
    sys.exit(rc)
